//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the PJRT CPU client.
//!
//! This is the only place Python output is consumed; after `make
//! artifacts` the binary is self-contained. Interchange is HLO *text*
//! (not serialized protos): jax >= 0.5 emits 64-bit instruction ids that
//! xla_extension 0.5.1 rejects, while the text parser reassigns ids.
//!
//! Entry points mirror the L2 model:
//!   - `fit_batch`      -> fit_b{B}_n{N}.hlo.txt
//!   - `predict_batch`  -> predict_b{B}.hlo.txt
//!   - `fit_predict`    -> fit_predict_b{B}_n{N}.hlo.txt (fused hot path)
//!   - `wastage_batch`  -> wastage_b{B}_n{N}.hlo.txt
//!
//! Inputs are padded/masked to the bucket shapes and chunked when they
//! exceed the batch bucket; results are unpadded before returning.
//!
//! This module only exists behind the `pjrt` cargo feature. The offline
//! workspace resolves the `xla` dependency to the in-tree API stub
//! (`vendor/xla`), which type-checks this whole path and returns clear
//! runtime errors for device operations; swap the dependency for the
//! real xla-rs bindings to execute artifacts.

pub mod manifest;

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::predictor::regression::{FitEngine, LinModel};
use manifest::Manifest;

/// A loaded PJRT executable plus its entry metadata.
struct Entry {
    exe: xla::PjRtLoadedExecutable,
}

/// The PJRT-backed numeric runtime.
///
/// `fit`/`fit_predict` carry one executable per observation bucket
/// (ascending); calls pick the smallest bucket that holds the longest
/// row, so typical training histories (< 64 executions) run on the
/// small artifact at ~1/8 the cost of the 512-wide one (§Perf).
pub struct Runtime {
    manifest: Manifest,
    fit: Vec<(usize, Entry)>,
    predict: Entry,
    fit_predict: Vec<(usize, Entry)>,
    wastage: Entry,
    plan_wastage: Entry,
}

/// Resolve the artifacts directory at *runtime*: the `KSPLUS_ARTIFACTS`
/// env var wins; otherwise search for an `artifacts/manifest.json` next
/// to the executable and in its ancestor directories (so a binary in
/// `target/release/` finds a checkout-level `artifacts/`, and a deployed
/// binary finds a sibling directory); finally fall back to `./artifacts`.
///
/// Deliberately NOT `env!("CARGO_MANIFEST_DIR")`: that constant is the
/// build machine's absolute path and would be baked into release
/// binaries, pointing at a directory that does not exist on any other
/// host.
pub fn default_artifacts_dir() -> PathBuf {
    resolve_artifacts_dir(
        std::env::var_os("KSPLUS_ARTIFACTS").map(PathBuf::from),
        std::env::current_exe().ok(),
    )
}

/// Pure resolution core of [`default_artifacts_dir`], separated so tests
/// can drive it without mutating process-global environment state.
fn resolve_artifacts_dir(override_dir: Option<PathBuf>, exe: Option<PathBuf>) -> PathBuf {
    if let Some(p) = override_dir {
        return p;
    }
    if let Some(exe) = exe {
        let mut dir: Option<&Path> = exe.parent();
        while let Some(d) = dir {
            let candidate = d.join("artifacts");
            if candidate.join("manifest.json").exists() {
                return candidate;
            }
            dir = d.parent();
        }
    }
    PathBuf::from("artifacts")
}

impl Runtime {
    /// Load and compile all artifacts. One PJRT client, one compiled
    /// executable per model — compile happens once at startup, never on
    /// the request path.
    pub fn load(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {dir:?} (run `make artifacts`)"))?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let compile = |file: &str| -> Result<Entry> {
            let path = dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .with_context(|| format!("parsing {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).with_context(|| format!("compiling {file}"))?;
            Ok(Entry { exe })
        };
        let compile_buckets = |kind: &str| -> Result<Vec<(usize, Entry)>> {
            let files = manifest.entry_files(kind);
            anyhow::ensure!(!files.is_empty(), "no artifact entry of kind '{kind}'");
            files.into_iter().map(|(n, f)| Ok((n, compile(&f)?))).collect()
        };
        Ok(Runtime {
            fit: compile_buckets("fit")?,
            predict: compile(&manifest.entry_file("predict")?)?,
            fit_predict: compile_buckets("fit_predict")?,
            wastage: compile(&manifest.entry_file("wastage")?)?,
            plan_wastage: compile(&manifest.entry_file("plan_wastage")?)?,
            manifest,
        })
    }

    /// Convenience: load from the default location.
    pub fn load_default() -> Result<Runtime> {
        Self::load(&default_artifacts_dir())
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    // ---- shape helpers ---------------------------------------------------

    fn lit2(data: &[f32], b: usize, n: usize) -> Result<xla::Literal> {
        Ok(xla::Literal::vec1(data).reshape(&[b as i64, n as i64])?)
    }

    fn lit1(data: &[f32]) -> xla::Literal {
        xla::Literal::vec1(data)
    }

    /// Pad `rows` of (xs, ys) into x/y/mask buckets of shape [b, n].
    fn pack_rows(
        rows: &[(Vec<f64>, Vec<f64>)],
        b: usize,
        n: usize,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut x = vec![0f32; b * n];
        let mut y = vec![0f32; b * n];
        let mut m = vec![0f32; b * n];
        for (i, (xs, ys)) in rows.iter().enumerate() {
            let len = xs.len().min(n);
            for j in 0..len {
                x[i * n + j] = xs[j] as f32;
                y[i * n + j] = ys[j] as f32;
                m[i * n + j] = 1.0;
            }
        }
        (x, y, m)
    }

    fn exec1(entry: &Entry, inputs: &[xla::Literal]) -> Result<xla::Literal> {
        let result = entry.exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        Ok(result)
    }

    /// Smallest observation bucket holding `max_obs` (else the largest;
    /// longer rows are truncated by `pack_rows`).
    fn pick_bucket<'a>(buckets: &'a [(usize, Entry)], max_obs: usize) -> (usize, &'a Entry) {
        for (n, e) in buckets {
            if *n >= max_obs {
                return (*n, e);
            }
        }
        let (n, e) = buckets.last().expect("no buckets");
        (*n, e)
    }

    // ---- public ops ------------------------------------------------------

    /// Batched masked OLS on the PJRT device. Chunks beyond the bucket;
    /// per chunk, runs on the smallest observation bucket that fits.
    pub fn fit_batch(&self, rows: &[(Vec<f64>, Vec<f64>)]) -> Result<Vec<LinModel>> {
        let b = self.manifest.fit_b;
        let mut out = Vec::with_capacity(rows.len());
        for chunk in rows.chunks(b) {
            let max_obs = chunk.iter().map(|(xs, _)| xs.len()).max().unwrap_or(0);
            let (n, entry) = Self::pick_bucket(&self.fit, max_obs);
            let (x, y, m) = Self::pack_rows(chunk, b, n);
            let lits = [
                Self::lit2(&x, b, n)?,
                Self::lit2(&y, b, n)?,
                Self::lit2(&m, b, n)?,
            ];
            let coef = Self::exec1(entry, &lits)?.to_tuple1()?;
            let v = coef.to_vec::<f32>()?;
            if v.len() != b * 2 {
                bail!("fit artifact returned {} values, want {}", v.len(), b * 2);
            }
            for i in 0..chunk.len() {
                out.push(LinModel { slope: v[i * 2] as f64, intercept: v[i * 2 + 1] as f64 });
            }
        }
        Ok(out)
    }

    /// Batched affine predict with per-row safety scale.
    pub fn predict_batch(
        &self,
        models: &[LinModel],
        xq: &[f64],
        scale: &[f64],
    ) -> Result<Vec<f64>> {
        assert_eq!(models.len(), xq.len());
        assert_eq!(models.len(), scale.len());
        let b = self.manifest.predict_b;
        let mut out = Vec::with_capacity(xq.len());
        let idx: Vec<usize> = (0..models.len()).collect();
        for chunk in idx.chunks(b) {
            let mut coef = vec![0f32; b * 2];
            let mut x = vec![0f32; b];
            let mut s = vec![0f32; b];
            for (i, &r) in chunk.iter().enumerate() {
                coef[i * 2] = models[r].slope as f32;
                coef[i * 2 + 1] = models[r].intercept as f32;
                x[i] = xq[r] as f32;
                s[i] = scale[r] as f32;
            }
            let lits = [Self::lit2(&coef, b, 2)?, Self::lit1(&x), Self::lit1(&s)];
            let y = Self::exec1(&self.predict, &lits)?.to_tuple1()?;
            let v = y.to_vec::<f32>()?;
            for i in 0..chunk.len() {
                out.push(v[i] as f64);
            }
        }
        Ok(out)
    }

    /// Fused fit + predict: one device round trip per bucket. Returns
    /// (predictions, fitted models).
    pub fn fit_predict(
        &self,
        rows: &[(Vec<f64>, Vec<f64>)],
        xq: &[f64],
        scale: &[f64],
    ) -> Result<(Vec<f64>, Vec<LinModel>)> {
        assert_eq!(rows.len(), xq.len());
        assert_eq!(rows.len(), scale.len());
        let b = self.manifest.fit_b;
        let mut preds = Vec::with_capacity(rows.len());
        let mut models = Vec::with_capacity(rows.len());
        let mut offset = 0usize;
        for chunk in rows.chunks(b) {
            let max_obs = chunk.iter().map(|(xs, _)| xs.len()).max().unwrap_or(0);
            let (n, entry) = Self::pick_bucket(&self.fit_predict, max_obs);
            let (x, y, m) = Self::pack_rows(chunk, b, n);
            let mut q = vec![0f32; b];
            let mut s = vec![0f32; b];
            for i in 0..chunk.len() {
                q[i] = xq[offset + i] as f32;
                s[i] = scale[offset + i] as f32;
            }
            let lits = [
                Self::lit2(&x, b, n)?,
                Self::lit2(&y, b, n)?,
                Self::lit2(&m, b, n)?,
                Self::lit1(&q),
                Self::lit1(&s),
            ];
            let (yhat, coef) = Self::exec1(entry, &lits)?.to_tuple2()?;
            let yv = yhat.to_vec::<f32>()?;
            let cv = coef.to_vec::<f32>()?;
            for i in 0..chunk.len() {
                preds.push(yv[i] as f64);
                models.push(LinModel {
                    slope: cv[i * 2] as f64,
                    intercept: cv[i * 2 + 1] as f64,
                });
            }
            offset += chunk.len();
        }
        Ok((preds, models))
    }

    /// Batched plan-vs-trace wastage in GB*s: rows of
    /// (alloc samples, used samples, dt).
    pub fn wastage_batch(&self, rows: &[(Vec<f64>, Vec<f64>, f64)]) -> Result<Vec<f64>> {
        let (b, n) = (self.manifest.fit_b, self.manifest.fit_n);
        let mut out = Vec::with_capacity(rows.len());
        for chunk in rows.chunks(b) {
            let mut alloc = vec![0f32; b * n];
            let mut used = vec![0f32; b * n];
            let mut m = vec![0f32; b * n];
            let mut dt = vec![0f32; b];
            for (i, (a, u, d)) in chunk.iter().enumerate() {
                let len = a.len().min(n);
                for j in 0..len {
                    alloc[i * n + j] = a[j] as f32;
                    used[i * n + j] = u[j] as f32;
                    m[i * n + j] = 1.0;
                }
                dt[i] = *d as f32;
            }
            let lits = [
                Self::lit2(&alloc, b, n)?,
                Self::lit2(&used, b, n)?,
                Self::lit2(&m, b, n)?,
                Self::lit1(&dt),
            ];
            let w = Self::exec1(&self.wastage, &lits)?.to_tuple1()?;
            let v = w.to_vec::<f32>()?;
            for i in 0..chunk.len() {
                out.push(v[i] as f64);
            }
        }
        Ok(out)
    }
}

impl Runtime {
    /// Batched step-plan scoring: wastage of `plan` against the usage
    /// trace, per row, without materialising the allocation host-side.
    /// Plans with more than `manifest.plan_k` segments are rejected.
    pub fn plan_wastage_batch(
        &self,
        rows: &[(crate::segments::StepPlan, Vec<f64>, f64)],
    ) -> Result<Vec<f64>> {
        let (b, n, k) = (self.manifest.fit_b, self.manifest.fit_n, self.manifest.plan_k);
        let mut out = Vec::with_capacity(rows.len());
        for chunk in rows.chunks(b) {
            let mut starts = vec![0f32; b * k];
            let mut peaks = vec![0f32; b * k];
            let mut used = vec![0f32; b * n];
            let mut m = vec![0f32; b * n];
            let mut dt = vec![0f32; b];
            for (i, (plan, u, d)) in chunk.iter().enumerate() {
                if plan.k() > k {
                    bail!("plan has {} segments, artifact supports {k}", plan.k());
                }
                for j in 0..k {
                    // Pad by repeating the last segment.
                    let src = j.min(plan.k() - 1);
                    starts[i * k + j] = plan.starts[src] as f32;
                    peaks[i * k + j] = plan.peaks[src] as f32;
                }
                let len = u.len().min(n);
                for j in 0..len {
                    used[i * n + j] = u[j] as f32;
                    m[i * n + j] = 1.0;
                }
                dt[i] = *d as f32;
            }
            let lits = [
                Self::lit2(&starts, b, k)?,
                Self::lit2(&peaks, b, k)?,
                Self::lit2(&used, b, n)?,
                Self::lit2(&m, b, n)?,
                Self::lit1(&dt),
            ];
            let w = Self::exec1(&self.plan_wastage, &lits)?.to_tuple1()?;
            let v = w.to_vec::<f32>()?;
            for i in 0..chunk.len() {
                out.push(v[i] as f64);
            }
        }
        Ok(out)
    }
}

/// `FitEngine` adapter so predictors can train on the PJRT path.
/// `Rc`, not `Arc`: the PJRT handles are thread-affine.
pub struct PjrtFitEngine(pub std::rc::Rc<Runtime>);

impl FitEngine for PjrtFitEngine {
    fn fit_batch(&self, rows: &[(Vec<f64>, Vec<f64>)]) -> Vec<LinModel> {
        self.0.fit_batch(rows).expect("PJRT fit failed")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predictor::regression::NativeFit;
    use crate::util::rng::Rng;

    // PJRT handles are thread-affine, so each test loads its own
    // runtime (compile cost is small on the CPU client).
    fn runtime() -> Option<Runtime> {
        let dir = default_artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("SKIP: artifacts not built (run `make artifacts`)");
            return None;
        }
        Some(Runtime::load(&dir).expect("runtime load"))
    }

    #[test]
    fn artifacts_dir_env_override_wins() {
        // Drives the pure resolver directly — no process-global env
        // mutation, so parallel tests cannot race.
        let got = resolve_artifacts_dir(
            Some(PathBuf::from("/opt/ksplus-override")),
            Some(PathBuf::from("/ignored/bin/repro")),
        );
        assert_eq!(got, PathBuf::from("/opt/ksplus-override"));
    }

    #[test]
    fn artifacts_dir_is_not_baked_from_build_machine() {
        // Without an override the result is either an artifacts dir with
        // a manifest discovered near the executable, or the relative
        // ./artifacts fallback — never a baked-in absolute build path.
        let dir = resolve_artifacts_dir(None, std::env::current_exe().ok());
        if dir.is_absolute() {
            assert!(dir.join("manifest.json").exists(), "{dir:?}");
        } else {
            assert_eq!(dir, PathBuf::from("artifacts"));
        }
        // No executable context at all degrades to the cwd fallback.
        assert_eq!(resolve_artifacts_dir(None, None), PathBuf::from("artifacts"));
    }

    fn rand_rows(rng: &mut Rng, count: usize, max_n: usize) -> Vec<(Vec<f64>, Vec<f64>)> {
        (0..count)
            .map(|_| {
                let n = 1 + rng.below(max_n);
                let xs: Vec<f64> = (0..n).map(|_| rng.uniform(0.0, 1000.0)).collect();
                let ys: Vec<f64> =
                    xs.iter().map(|x| 0.003 * x + 2.0 + rng.normal_ms(0.0, 0.3)).collect();
                (xs, ys)
            })
            .collect()
    }

    #[test]
    fn fit_matches_native() {
        let Some(rt) = runtime() else { return };
        let mut rng = Rng::new(1);
        let rows = rand_rows(&mut rng, 40, 100);
        let pjrt = rt.fit_batch(&rows).unwrap();
        let native = NativeFit.fit_batch(&rows);
        for (p, n) in pjrt.iter().zip(&native) {
            assert!((p.slope - n.slope).abs() < 1e-3, "{p:?} vs {n:?}");
            assert!((p.intercept - n.intercept).abs() < 5e-2, "{p:?} vs {n:?}");
        }
    }

    #[test]
    fn fit_chunks_beyond_bucket() {
        let Some(rt) = runtime() else { return };
        let mut rng = Rng::new(2);
        let b = rt.manifest().fit_b;
        let rows = rand_rows(&mut rng, b + 17, 20);
        let pjrt = rt.fit_batch(&rows).unwrap();
        assert_eq!(pjrt.len(), b + 17);
        let native = NativeFit.fit_batch(&rows);
        for (p, n) in pjrt.iter().zip(&native) {
            assert!((p.slope - n.slope).abs() < 1e-3);
        }
    }

    #[test]
    fn predict_matches_native() {
        let Some(rt) = runtime() else { return };
        let mut rng = Rng::new(3);
        let models: Vec<LinModel> = (0..50)
            .map(|_| LinModel { slope: rng.uniform(-2.0, 2.0), intercept: rng.uniform(-5.0, 5.0) })
            .collect();
        let xq: Vec<f64> = (0..50).map(|_| rng.uniform(0.0, 100.0)).collect();
        let scale: Vec<f64> =
            (0..50).map(|_| if rng.below(2) == 0 { 1.1 } else { 0.85 }).collect();
        let got = rt.predict_batch(&models, &xq, &scale).unwrap();
        for i in 0..50 {
            let want = (models[i].predict(xq[i]) * scale[i]).max(0.0);
            assert!((got[i] - want).abs() < 1e-3, "row {i}: {} vs {want}", got[i]);
        }
    }

    #[test]
    fn fused_matches_two_step() {
        let Some(rt) = runtime() else { return };
        let mut rng = Rng::new(4);
        let rows = rand_rows(&mut rng, 30, 60);
        let xq: Vec<f64> = (0..30).map(|_| rng.uniform(0.0, 1000.0)).collect();
        let scale = vec![1.1; 30];
        let (preds, models) = rt.fit_predict(&rows, &xq, &scale).unwrap();
        let models2 = rt.fit_batch(&rows).unwrap();
        let preds2 = rt.predict_batch(&models2, &xq, &scale).unwrap();
        for i in 0..30 {
            assert!((preds[i] - preds2[i]).abs() < 2e-2, "{} vs {}", preds[i], preds2[i]);
            assert!((models[i].slope - models2[i].slope).abs() < 1e-4);
        }
    }

    #[test]
    fn wastage_matches_native() {
        let Some(rt) = runtime() else { return };
        let mut rng = Rng::new(5);
        let rows: Vec<(Vec<f64>, Vec<f64>, f64)> = (0..20)
            .map(|_| {
                let n = 1 + rng.below(200);
                let alloc: Vec<f64> = (0..n).map(|_| rng.uniform(0.0, 32.0)).collect();
                let used: Vec<f64> = (0..n).map(|_| rng.uniform(0.0, 32.0)).collect();
                (alloc, used, rng.uniform(0.2, 10.0))
            })
            .collect();
        let got = rt.wastage_batch(&rows).unwrap();
        for (i, (a, u, dt)) in rows.iter().enumerate() {
            let want: f64 =
                a.iter().zip(u).map(|(x, y)| (x - y).max(0.0)).sum::<f64>() * dt;
            let tol = want.abs().max(1.0) * 1e-4;
            assert!((got[i] - want).abs() < tol, "row {i}: {} vs {want}", got[i]);
        }
    }

    #[test]
    fn plan_wastage_matches_host_side() {
        let Some(rt) = runtime() else { return };
        use crate::segments::StepPlan;
        let mut rng = Rng::new(9);
        let rows: Vec<(StepPlan, Vec<f64>, f64)> = (0..30)
            .map(|_| {
                let segs = 1 + rng.below(rt.manifest().plan_k);
                let mut starts = vec![0.0];
                let mut peaks = vec![rng.uniform(0.5, 4.0)];
                for _ in 1..segs {
                    starts.push(starts.last().unwrap() + rng.uniform(1.0, 30.0));
                    peaks.push(peaks.last().unwrap() + rng.uniform(0.0, 4.0));
                }
                let plan = StepPlan::new(starts, peaks);
                let n = 1 + rng.below(300);
                let dt = rng.uniform(0.2, 4.0);
                let used: Vec<f64> = (0..n).map(|_| rng.uniform(0.0, 12.0)).collect();
                (plan, used, dt)
            })
            .collect();
        let got = rt.plan_wastage_batch(&rows).unwrap();
        for (i, (plan, used, dt)) in rows.iter().enumerate() {
            let e = crate::trace::Execution::new("t", 1.0, *dt, used.clone());
            let want = plan.wastage_gbs(&e);
            let tol = want.abs().max(1.0) * 1e-3;
            assert!((got[i] - want).abs() < tol, "row {i}: {} vs {want}", got[i]);
        }
    }

    #[test]
    fn plan_wastage_rejects_oversized_plans() {
        let Some(rt) = runtime() else { return };
        use crate::segments::StepPlan;
        let k = rt.manifest().plan_k;
        let starts: Vec<f64> = (0..=k).map(|i| i as f64).collect();
        let peaks: Vec<f64> = (1..=k + 1).map(|i| i as f64).collect();
        let plan = StepPlan::new(starts, peaks);
        assert!(rt.plan_wastage_batch(&[(plan, vec![1.0], 1.0)]).is_err());
    }

    #[test]
    fn degenerate_rows_handled() {
        let Some(rt) = runtime() else { return };
        // Empty, single-point, constant-x rows.
        let rows = vec![
            (vec![], vec![]),
            (vec![4.0], vec![12.0]),
            (vec![3.0, 3.0, 3.0], vec![1.0, 2.0, 3.0]),
        ];
        let got = rt.fit_batch(&rows).unwrap();
        assert_eq!(got[0], LinModel { slope: 0.0, intercept: 0.0 });
        assert!((got[1].intercept - 12.0).abs() < 1e-4);
        assert!(got[1].slope.abs() < 1e-6);
        assert!(got[2].slope.abs() < 1e-6);
        assert!((got[2].intercept - 2.0).abs() < 1e-4);
    }

    #[test]
    fn pjrt_engine_trains_ksplus_like_native() {
        let Some(rt) = runtime() else { return };
        use crate::predictor::ksplus::KsPlus;
        use crate::predictor::Predictor;
        use crate::trace::Execution;
        let mut rng = Rng::new(6);
        let hist: Vec<Execution> = (0..25)
            .map(|_| {
                let input = rng.uniform(1000.0, 9000.0);
                let n = ((input * 0.01) as usize).max(4);
                let half = n / 2;
                let mut s = vec![input * 0.0004; half];
                s.extend(vec![input * 0.0009; n - half]);
                Execution::new("t", input, 1.0, s)
            })
            .collect();
        let mut native = KsPlus::new(3, 128.0);
        native.train(&hist);
        let mut viapjrt = KsPlus::new(3, 128.0);
        struct Borrowed<'a>(&'a Runtime);
        impl FitEngine for Borrowed<'_> {
            fn fit_batch(&self, rows: &[(Vec<f64>, Vec<f64>)]) -> Vec<LinModel> {
                self.0.fit_batch(rows).unwrap()
            }
        }
        viapjrt.train_with_engine(&hist, &Borrowed(&rt));
        let a = native.plan(5000.0);
        let b = viapjrt.plan(5000.0);
        assert_eq!(a.k(), b.k());
        for i in 0..a.k() {
            assert!((a.starts[i] - b.starts[i]).abs() < 0.5, "{a:?} vs {b:?}");
            assert!((a.peaks[i] - b.peaks[i]).abs() < 0.05, "{a:?} vs {b:?}");
        }
    }
}
