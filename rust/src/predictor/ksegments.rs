//! k-Segments baseline [19]: equally sized segments over a predicted
//! runtime, per-segment peak regressions, and the Selective / Partial
//! failure-offset strategies.

use crate::predictor::regression::{LinModel, NativeFit, FitEngine};
use crate::predictor::{sanitize_plan, Predictor};
use crate::segments::StepPlan;
use crate::trace::Execution;

/// Offsets mirroring the original method's safety strategy.
const MEM_OVERPREDICT: f64 = 1.10;
const RUNTIME_UNDERPREDICT: f64 = 0.85;
/// Multiplicative offset applied by the retry strategies.
const RETRY_OFFSET: f64 = 2.0;

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RetryMode {
    /// Offset only the failed segment (k-Segments Selective).
    Selective,
    /// Offset the failed segment and everything after (k-Segments Partial).
    Partial,
}

pub struct KSegments {
    k: usize,
    capacity: f64,
    mode: RetryMode,
    runtime_model: Option<LinModel>,
    peak_models: Vec<LinModel>,
    fallback_peak: f64,
}

impl KSegments {
    pub fn new(k: usize, capacity: f64, mode: RetryMode) -> Self {
        assert!(k >= 1);
        KSegments {
            k,
            capacity,
            mode,
            runtime_model: None,
            peak_models: Vec::new(),
            fallback_peak: 2.0,
        }
    }

    /// Peak of each of the k equal slices of an execution.
    fn slice_peaks(&self, e: &Execution) -> Vec<f64> {
        let n = e.samples.len();
        let mut out = Vec::with_capacity(self.k);
        for j in 0..self.k {
            let lo = j * n / self.k;
            let hi = ((j + 1) * n / self.k).max(lo + 1).min(n.max(1));
            let peak = e.samples[lo.min(n.saturating_sub(1))..hi]
                .iter()
                .cloned()
                .fold(0.0, f64::max);
            out.push(peak);
        }
        out
    }
}

impl Predictor for KSegments {
    fn name(&self) -> &'static str {
        match self.mode {
            RetryMode::Selective => "ksegments-selective",
            RetryMode::Partial => "ksegments-partial",
        }
    }

    fn train(&mut self, history: &[Execution]) {
        if history.is_empty() {
            self.runtime_model = None;
            return;
        }
        // All k+1 regressions (runtime + k slice peaks) share the input
        // sizes as their x-column — fit them through `fit_shared` so the
        // x-statistics are computed once instead of cloning the column.
        let inputs: Vec<f64> = history.iter().map(|e| e.input_mb).collect();
        let durations: Vec<f64> = history.iter().map(|e| e.duration()).collect();
        let mut cols: Vec<Vec<f64>> = vec![durations];
        let per_exec: Vec<Vec<f64>> = history.iter().map(|e| self.slice_peaks(e)).collect();
        for j in 0..self.k {
            cols.push(per_exec.iter().map(|p| p[j]).collect());
        }
        let models = NativeFit.fit_shared(&inputs, &cols);
        self.runtime_model = Some(models[0]);
        self.peak_models = models[1..].to_vec();
        self.fallback_peak =
            history.iter().map(|e| e.peak()).fold(0.0, f64::max).max(0.1);
    }

    fn plan(&self, input_mb: f64) -> StepPlan {
        let Some(rt) = self.runtime_model else {
            return StepPlan::flat(self.fallback_peak.min(self.capacity));
        };
        // Underpredicted runtime split into k equal segments.
        let runtime = (rt.predict(input_mb) * RUNTIME_UNDERPREDICT).max(1.0);
        let seg = runtime / self.k as f64;
        let starts: Vec<f64> = (0..self.k).map(|j| j as f64 * seg).collect();
        let peaks: Vec<f64> = self
            .peak_models
            .iter()
            .map(|m| (m.predict(input_mb) * MEM_OVERPREDICT).max(1e-3))
            .collect();
        // Monotonicity is enforced (running max) like KS+ — equal-sized
        // segments otherwise release memory mid-run and fail instantly
        // for any later-peaking task.
        sanitize_plan(starts, peaks, self.capacity)
    }

    fn on_failure(&self, prev: &StepPlan, fail_time: f64, _attempt: usize) -> StepPlan {
        if prev.k() == 0 {
            // Degenerate empty plan: fall back to a flat allocation.
            return StepPlan::flat(self.fallback_peak.min(self.capacity));
        }
        let i = prev.segment_at(fail_time);
        let mut peaks = prev.peaks.clone();
        match self.mode {
            RetryMode::Selective => {
                peaks[i] = (peaks[i] * RETRY_OFFSET).min(self.capacity);
            }
            RetryMode::Partial => {
                for p in peaks.iter_mut().skip(i) {
                    *p = (*p * RETRY_OFFSET).min(self.capacity);
                }
            }
        }
        sanitize_plan(prev.starts.clone(), peaks, self.capacity)
    }

    fn capacity(&self) -> f64 {
        self.capacity
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::run_prop;
    use crate::util::rng::Rng;

    fn exec(input: f64, rng: &mut Rng) -> Execution {
        // Linear in input: duration input*0.02 s, two plateaus.
        let n = ((input * 0.02) as usize).max(4);
        let half = n / 2;
        let mut s = vec![input * 0.0004; half];
        s.extend(vec![input * 0.0009; n - half]);
        for v in s.iter_mut() {
            *v *= 1.0 - 0.02 * rng.f64();
        }
        Execution::new("t", input, 1.0, s)
    }

    fn trained(mode: RetryMode) -> KSegments {
        let mut rng = Rng::new(2);
        let hist: Vec<Execution> =
            (0..40).map(|_| exec(rng.uniform(2000.0, 10000.0), &mut rng)).collect();
        let mut p = KSegments::new(4, 128.0, mode);
        p.train(&hist);
        p
    }

    #[test]
    fn plan_has_equal_sized_segments() {
        let p = trained(RetryMode::Selective);
        let plan = p.plan(8000.0);
        assert!(plan.is_valid());
        // sanitize may merge equal-peak neighbours; check spacing of the
        // surviving boundaries is a multiple of the base segment size.
        let runtime = plan.starts.last().unwrap() * 4.0 / 3.0; // k=4
        let seg = runtime / 4.0;
        for w in plan.starts.windows(2) {
            let gap = w[1] - w[0];
            let ratio = gap / seg;
            assert!((ratio - ratio.round()).abs() < 0.05, "gap {gap} vs seg {seg}");
        }
    }

    #[test]
    fn untrained_fallback() {
        let p = KSegments::new(4, 128.0, RetryMode::Partial);
        assert_eq!(p.plan(1000.0).k(), 1);
    }

    #[test]
    fn selective_offsets_only_failed_segment() {
        let p = trained(RetryMode::Selective);
        let prev = StepPlan::new(vec![0.0, 30.0, 60.0], vec![2.0, 4.0, 8.0]);
        let retry = p.on_failure(&prev, 35.0, 1);
        // Failed segment 1: 4 -> 8; segment 2 stays 8 (merged by equal
        // peak or kept).
        assert_eq!(retry.alloc_at(0.0), 2.0);
        assert_eq!(retry.alloc_at(35.0), 8.0);
        assert_eq!(retry.alloc_at(100.0), 8.0);
    }

    #[test]
    fn partial_offsets_failed_and_following() {
        let p = trained(RetryMode::Partial);
        let prev = StepPlan::new(vec![0.0, 30.0, 60.0], vec![2.0, 4.0, 8.0]);
        let retry = p.on_failure(&prev, 35.0, 1);
        assert_eq!(retry.alloc_at(0.0), 2.0);
        assert_eq!(retry.alloc_at(35.0), 8.0);
        assert_eq!(retry.alloc_at(100.0), 16.0);
    }

    #[test]
    fn retry_clamps_to_capacity() {
        let p = trained(RetryMode::Partial);
        let prev = StepPlan::new(vec![0.0, 10.0], vec![70.0, 90.0]);
        let retry = p.on_failure(&prev, 15.0, 1);
        assert!(retry.peaks.iter().all(|&x| x <= 128.0));
    }

    #[test]
    fn covers_most_unseen_executions() {
        let p = trained(RetryMode::Selective);
        let mut rng = Rng::new(77);
        let total = 40;
        let covered = (0..total)
            .filter(|_| {
                let e = exec(rng.uniform(2500.0, 9500.0), &mut rng);
                p.plan(e.input_mb).covers(&e)
            })
            .count();
        assert!(covered >= total * 7 / 10, "{covered}/{total}");
    }

    #[test]
    fn prop_plans_and_retries_valid() {
        run_prop("ksegments_valid", 100, |rng| {
            let k = 1 + rng.below(6);
            let mode = if rng.below(2) == 0 { RetryMode::Selective } else { RetryMode::Partial };
            let hist: Vec<Execution> = (0..4 + rng.below(15))
                .map(|_| {
                    let n = 4 + rng.below(50);
                    Execution::new(
                        "t",
                        rng.uniform(100.0, 8000.0),
                        1.0,
                        (0..n).map(|_| rng.uniform(0.1, 10.0)).collect(),
                    )
                })
                .collect();
            let mut p = KSegments::new(k, 128.0, mode);
            p.train(&hist);
            let plan = p.plan(rng.uniform(50.0, 16000.0));
            assert!(plan.is_valid());
            let retry = p.on_failure(&plan, rng.uniform(0.0, 300.0), 1);
            assert!(retry.is_valid());
            // Retry never lowers allocation anywhere.
            for i in 0..50 {
                let t = i as f64 * 7.0;
                assert!(retry.alloc_at(t) + 1e-9 >= plan.alloc_at(t));
            }
        });
    }
}
