"""Pallas kernels vs pure-jnp oracle: the core L1 correctness signal.

Hypothesis sweeps shapes (block-aligned and ragged-masked), value ranges
(GB-scale memory, second-scale times), and degenerate rows (n<2, zero
variance); every case asserts allclose against ref.py.
"""

from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import ols, ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def _mk(rng, b, n, lo, hi):
    return rng.uniform(lo, hi, size=(b, n)).astype(np.float32)


def _mask(rng, b, n, min_obs=0):
    counts = rng.integers(min_obs, n + 1, size=b)
    m = np.zeros((b, n), np.float32)
    for i, c in enumerate(counts):
        m[i, :c] = 1.0
    return m


# ---------------------------------------------------------------- fit


@pytest.mark.parametrize("b,n", [(2, 4), (8, 16), (128, 64), (256, 32)])
def test_fit_matches_ref_dense(b, n):
    rng = np.random.default_rng(b * 1000 + n)
    x = _mk(rng, b, n, 0.1, 100.0)
    y = 3.5 * x + 7.0 + rng.normal(0, 0.5, size=(b, n)).astype(np.float32)
    m = np.ones((b, n), np.float32)
    got = ols.fit(x, y, m)
    want = ref.fit_ref(x, y, m)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


def test_fit_recovers_exact_line():
    b, n = 128, 16
    rng = np.random.default_rng(0)
    x = _mk(rng, b, n, 1.0, 50.0)
    slopes = rng.uniform(-5, 5, size=(b, 1)).astype(np.float32)
    icepts = rng.uniform(-10, 10, size=(b, 1)).astype(np.float32)
    y = slopes * x + icepts
    m = np.ones((b, n), np.float32)
    coef = np.asarray(ols.fit(x, y, m))
    np.testing.assert_allclose(coef[:, 0], slopes[:, 0], rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(coef[:, 1], icepts[:, 0], rtol=1e-3, atol=2e-2)


def test_fit_masked_rows_match_unpadded():
    """A masked row must equal fitting only its unmasked prefix."""
    b, n = 128, 32
    rng = np.random.default_rng(7)
    x = _mk(rng, b, n, 0.5, 20.0)
    y = _mk(rng, b, n, 0.0, 64.0)
    m = _mask(rng, b, n, min_obs=2)
    coef = np.asarray(ols.fit(x, y, m))
    for i in range(0, b, 17):
        c = int(m[i].sum())
        got = np.asarray(
            ref.fit_ref(x[i : i + 1, :c], y[i : i + 1, :c], np.ones((1, c), np.float32))
        )[0]
        np.testing.assert_allclose(coef[i], got, rtol=1e-3, atol=1e-2)


def test_fit_degenerate_rows():
    """n==0 -> (0,0); n==1 -> (0, y0); zero x-variance -> (0, mean y)."""
    b, n = 128, 8
    x = np.ones((b, n), np.float32) * 4.0
    y = np.full((b, n), 12.0, np.float32)
    m = np.ones((b, n), np.float32)
    m[0] = 0.0  # no observations
    m[1] = 0.0
    m[1, 0] = 1.0  # single observation
    coef = np.asarray(ols.fit(x, y, m))
    np.testing.assert_allclose(coef[0], [0.0, 0.0], atol=1e-6)
    np.testing.assert_allclose(coef[1], [0.0, 12.0], atol=1e-5)
    # constant x: degenerate denominator -> slope 0, intercept mean(y)
    np.testing.assert_allclose(coef[2], [0.0, 12.0], atol=1e-5)


@given(
    b=st.sampled_from([2, 8, 64, 128, 256]),
    n=st.sampled_from([2, 8, 32, 512]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fit_hypothesis(b, n, seed):
    rng = np.random.default_rng(seed)
    x = _mk(rng, b, n, 0.0, 1000.0)
    y = _mk(rng, b, n, 0.0, 128.0)
    m = _mask(rng, b, n)
    got = np.asarray(ols.fit(x, y, m))
    want = np.asarray(ref.fit_ref(x, y, m))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-2)


# ---------------------------------------------------------------- predict


@given(
    b=st.sampled_from([2, 8, 128, 1024]),
    seed=st.integers(0, 2**31 - 1),
)
def test_predict_hypothesis(b, seed):
    rng = np.random.default_rng(seed)
    coef = rng.uniform(-10, 10, size=(b, 2)).astype(np.float32)
    xq = rng.uniform(0, 500, size=b).astype(np.float32)
    scale = rng.choice(np.asarray([0.85, 1.0, 1.1], np.float32), size=b)
    got = np.asarray(ols.predict(coef, xq, scale))
    want = np.asarray(ref.predict_ref(coef, xq, scale))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_predict_clamps_negative():
    coef = np.asarray([[-1.0, 0.0], [0.0, -5.0]], np.float32)
    xq = np.asarray([10.0, 1.0], np.float32)
    scale = np.ones(2, np.float32)
    got = np.asarray(ols.predict(coef, xq, scale))
    np.testing.assert_allclose(got, [0.0, 0.0])


def test_predict_safety_scales():
    """+10% memory / -15% time offsets are plain multiplicative scales."""
    coef = np.tile(np.asarray([[2.0, 1.0]], np.float32), (4, 1))
    xq = np.full(4, 3.0, np.float32)  # base = 7.0
    scale = np.asarray([1.0, 1.1, 0.85, 0.5], np.float32)
    got = np.asarray(ols.predict(coef, xq, scale))
    np.testing.assert_allclose(got, 7.0 * scale, rtol=1e-6)


# ---------------------------------------------------------------- wastage


@given(
    b=st.sampled_from([2, 128, 256]),
    n=st.sampled_from([4, 64, 512]),
    seed=st.integers(0, 2**31 - 1),
)
def test_wastage_hypothesis(b, n, seed):
    rng = np.random.default_rng(seed)
    alloc = _mk(rng, b, n, 0.0, 64.0)
    used = _mk(rng, b, n, 0.0, 64.0)
    m = _mask(rng, b, n)
    dt = rng.uniform(0.1, 30.0, size=b).astype(np.float32)
    got = np.asarray(ols.wastage(alloc, used, m, dt))
    want = np.asarray(ref.wastage_ref(alloc, used, m, dt))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_wastage_ignores_underallocation():
    """used > alloc contributes zero (failure cost is accounted in rust)."""
    b, n = 2, 4
    alloc = np.full((b, n), 2.0, np.float32)
    used = np.asarray(
        [[1.0, 1.0, 1.0, 1.0], [3.0, 3.0, 3.0, 3.0]], np.float32
    )
    m = np.ones((b, n), np.float32)
    dt = np.ones(b, np.float32)
    got = np.asarray(ols.wastage(alloc, used, m, dt))
    np.testing.assert_allclose(got, [4.0, 0.0])


def test_wastage_exact_value():
    alloc = np.asarray([[10.0, 10.0, 10.0, 0.0]], np.float32)
    used = np.asarray([[4.0, 6.0, 10.0, 0.0]], np.float32)
    m = np.asarray([[1.0, 1.0, 1.0, 0.0]], np.float32)
    dt = np.asarray([5.0], np.float32)
    got = np.asarray(ols.wastage(alloc, used, m, dt))
    np.testing.assert_allclose(got, [(6.0 + 4.0 + 0.0) * 5.0])


# ---------------------------------------------------------------- plan_wastage


def _mk_plans(rng, b, k):
    """Random monotone step plans padded to k segments."""
    starts = np.zeros((b, k), np.float32)
    peaks = np.zeros((b, k), np.float32)
    for i in range(b):
        segs = 1 + rng.integers(0, k)
        s, p = 0.0, rng.uniform(0.5, 4.0)
        for j in range(k):
            if j < segs:
                starts[i, j], peaks[i, j] = s, p
                s += rng.uniform(1.0, 20.0)
                p += rng.uniform(0.0, 4.0)
            else:  # pad: repeat last
                starts[i, j], peaks[i, j] = starts[i, j - 1], peaks[i, j - 1]
    return starts, peaks


@given(
    b=st.sampled_from([2, 8, 128]),
    n=st.sampled_from([4, 64, 256]),
    k=st.sampled_from([1, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_plan_wastage_hypothesis(b, n, k, seed):
    rng = np.random.default_rng(seed)
    starts, peaks = _mk_plans(rng, b, k)
    used = _mk(rng, b, n, 0.0, 16.0)
    m = _mask(rng, b, n)
    dt = rng.uniform(0.1, 5.0, size=b).astype(np.float32)
    got = np.asarray(ols.plan_wastage(starts, peaks, used, m, dt))
    want = np.asarray(ref.plan_wastage_ref(starts, peaks, used, m, dt))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


def test_plan_wastage_matches_manual_step_function():
    # Plan: 2 GB for [0, 10), then 5 GB. dt = 1, 20 samples of 1 GB used.
    starts = np.asarray([[0.0, 10.0]], np.float32)
    peaks = np.asarray([[2.0, 5.0]], np.float32)
    used = np.ones((1, 20), np.float32)
    m = np.ones((1, 20), np.float32)
    dt = np.asarray([1.0], np.float32)
    got = np.asarray(ols.plan_wastage(starts, peaks, used, m, dt))
    # 10 samples waste 1, 10 samples waste 4.
    np.testing.assert_allclose(got, [50.0], rtol=1e-6)


def test_plan_wastage_underallocation_contributes_zero():
    starts = np.asarray([[0.0]], np.float32)
    peaks = np.asarray([[1.0]], np.float32)
    used = np.full((1, 4), 3.0, np.float32)
    m = np.ones((1, 4), np.float32)
    dt = np.asarray([2.0], np.float32)
    got = np.asarray(ols.plan_wastage(starts, peaks, used, m, dt))
    np.testing.assert_allclose(got, [0.0])
