"""Layer-2 JAX model: the batched KS+ regression pipeline.

Jittable entry points, each wrapping the Layer-1 Pallas kernels so that a
single HLO module per bucket is produced by aot.py:

  fit_model         -- fit one OLS model per row (task x segment x target).
  predict_model     -- evaluate fitted models with KS+ safety scales.
  fit_predict_model -- fused fit + predict, the coordinator hot path:
                       one artifact execution instead of two round trips.
  wastage_model     -- batched GB-seconds plan-vs-trace evaluation used by
                       the experiment harness for bulk scoring.

Python never runs at request time: aot.py lowers these once to HLO text
and the rust runtime executes the compiled artifacts.
"""

from __future__ import annotations

from compile.kernels import ols


def fit_model(x, y, m):
    """f32[B,N] x 3 -> (coef f32[B,2],)."""
    return (ols.fit(x, y, m),)


def predict_model(coef, xq, scale):
    """coef f32[B,2], xq f32[B], scale f32[B] -> (yhat f32[B],)."""
    return (ols.predict(coef, xq, scale),)


def fit_predict_model(x, y, m, xq, scale):
    """Fused fit + predict over the same bucket; single HLO round trip."""
    coef = ols.fit(x, y, m)
    return (ols.predict(coef, xq, scale), coef)


def wastage_model(alloc, used, m, dt):
    """f32[B,N] x 3, dt f32[B] -> (gbs f32[B],)."""
    return (ols.wastage(alloc, used, m, dt),)


def plan_wastage_model(starts, peaks, used, m, dt):
    """Step-plan scoring: starts/peaks f32[B,K], used/m f32[B,N],
    dt f32[B] -> (gbs f32[B],). Saves materialising the allocation
    series host-side for bulk experiment scoring."""
    return (ols.plan_wastage(starts, peaks, used, m, dt),)
