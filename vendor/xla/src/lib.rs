//! API-compatible stub of the `xla` (xla-rs) PJRT bindings.
//!
//! The container has no XLA/PJRT shared libraries and no network access,
//! so the real FFI crate cannot be built here. This stub keeps the exact
//! call surface `ksplus::runtime` uses so the `pjrt` cargo feature
//! type-checks everywhere (`cargo check --features pjrt`), while every
//! operation that would need a real PJRT client returns a clear runtime
//! error instead of crashing or silently computing nothing.
//!
//! Deploying against real XLA is a dependency swap in `rust/Cargo.toml`
//! (point `xla` at the upstream bindings); no `ksplus` source changes.

use std::fmt;

/// Error type mirroring xla-rs: one displayable message.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what} is unavailable: this binary links the bundled XLA API stub \
         (no PJRT shared library in the build environment); swap the `xla` \
         dependency in rust/Cargo.toml for the real xla-rs bindings to \
         execute AOT artifacts"
    ))
}

/// Element types a `Literal` can be read back as.
pub trait Element: Copy + 'static {}
impl Element for f32 {}
impl Element for f64 {}
impl Element for i32 {}
impl Element for i64 {}

/// Host-side tensor value. Construction and reshape work (they are pure
/// host bookkeeping); device readbacks error.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 f32 literal.
    pub fn vec1(data: &[f32]) -> Literal {
        Literal { data: data.to_vec(), dims: vec![data.len() as i64] }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n != self.data.len() as i64 {
            return Err(Error(format!(
                "reshape: cannot view {} elements as {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn shape(&self) -> &[i64] {
        &self.dims
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        Err(unavailable("Literal::to_tuple1"))
    }

    pub fn to_tuple2(self) -> Result<(Literal, Literal)> {
        Err(unavailable("Literal::to_tuple2"))
    }

    pub fn to_vec<T: Element>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }
}

impl AsRef<Literal> for Literal {
    fn as_ref(&self) -> &Literal {
        self
    }
}

/// Parsed HLO module text (parsing is deferred to the real backend; the
/// stub only checks the file is readable).
#[derive(Debug)]
pub struct HloModuleProto {
    #[allow(dead_code)]
    text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error(format!("reading HLO text {path}: {e}")))?;
        Ok(HloModuleProto { text })
    }
}

pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L: AsRef<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_host_ops_work() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(l.shape(), &[4]);
        let m = l.reshape(&[2, 2]).unwrap();
        assert_eq!(m.shape(), &[2, 2]);
        assert!(l.reshape(&[3, 3]).is_err());
    }

    #[test]
    fn device_ops_error_clearly() {
        let err = PjRtClient::cpu().err().unwrap();
        let msg = err.to_string();
        assert!(msg.contains("stub"), "{msg}");
        assert!(Literal::vec1(&[1.0]).to_vec::<f32>().is_err());
    }
}
