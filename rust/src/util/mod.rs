//! Offline-build substrates: RNG, JSON, CLI, stats, property testing.

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
