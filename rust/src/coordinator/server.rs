//! Wire protocol server: newline-delimited JSON over TCP, the interface
//! a workflow engine (Nextflow plugin, Airflow operator) calls. The
//! protocol is **typed wire v1** — every request parses into
//! `protocol::Request`, every reply serializes from
//! `protocol::Response`, and malformed input maps to a structured
//! `protocol::WireError` (one specific `ErrorCode` per failure class).
//! The full schema lives in `docs/PROTOCOL.md`; the typed TCP client is
//! `coordinator::remote::RemoteClient`.
//!
//! Ops (one JSON object per line):
//!   {"op":"hello","min_version":1,"max_version":1}
//!   {"op":"configure","task":"bwa","policy":"witt-lr"}
//!   {"op":"train","task":"bwa","history":[{"input_mb":..,"dt":..,"samples":[..]},..]}
//!   {"op":"observe","task":"bwa","execution":{"input_mb":..,"dt":..,"samples":[..]}}
//!   {"op":"plan","task":"bwa","input_mb":8000.0}
//!   {"op":"failure","task":"bwa","plan":{"starts":[..],"peaks":[..]},"fail_time":624.0}
//!   {"op":"stats"}
//!   {"op":"snapshot"}
//!   {"op":"reshard","shards":4}
//!
//! `hello` negotiates the protocol version and advertises the op and
//! policy lists — a client checks that list for `"snapshot"` /
//! `"reshard"` before attempting the admin ops. `configure` binds a task
//! (or, without `task`, the service-wide default) to a predictor policy
//! at runtime. `plan` responses carry provenance — `predictor`,
//! `model_version`, `fallback_reason` — so callers can tell a trained
//! KS+ plan from a default-limits fallback. `failure` with a `task`
//! routes the retry through that task's bound policy. `snapshot` dumps
//! the full model state as a restorable document; `reshard` resizes the
//! worker pool in place (trained state migrates, plans are unchanged).
//!
//! Responses:
//!   {"ok":true, ...}                                     on success
//!   {"ok":false,"error":{"code":"...","message":"..."}}  on failure
//!
//! One OS thread per connection; every connection shares the coordinator
//! worker pool (and thus its per-shard dynamic batchers), so concurrent
//! clients' plan requests for tasks on the same shard are batched into
//! single backend executions (one PJRT dispatch per flush when built
//! with the `pjrt` feature). The `stats` op reports the merge across all
//! shards, plus the server's own connection counters.
//!
//! Framing goes through the [`wire`](crate::coordinator::wire) codec
//! seam: every connection starts on wire v1 (JSON lines) and may
//! negotiate the length-prefixed binary wire v2 via `hello` — after the
//! (still-v1) hello response, both directions switch. Request handling
//! itself lives in [`service::dispatch`], shared with the event-loop
//! front end in [`eventloop`](crate::coordinator::eventloop); this
//! thread-per-connection server is the simpler parity oracle.
//!
//! Connections are resource-bounded ([`ServerConfig`]): a request frame
//! larger than `max_frame_bytes` is answered with `request-too-large`
//! and the connection is closed (the remainder of an oversized frame
//! cannot be resynchronized); connections past `max_conns` are refused
//! with `too-many-connections`; a connection idle past `read_timeout` is
//! closed and counted. Handler threads are tracked and joined — not
//! detached — so `stop()` leaves no thread behind.

use std::io::{BufReader, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::coordinator::faults::FaultPlane;
use crate::coordinator::protocol::{ErrorCode, Request, Response, WireError};
use crate::coordinator::service::{
    dispatch_tapped, Client, ConnCounters, Coordinator, CoordinatorConfig, DispatchTap,
    Dispatched,
};
use crate::coordinator::wire::{
    decode_request, encode_error, read_frame, try_encode_response, FrameRead, Wire,
    DEFAULT_MAX_FRAME_BYTES, MAX_V2_PAYLOAD_BYTES,
};
use crate::coordinator::BackendSpec;

/// Default cap on one connection's buffered-but-unsent response bytes
/// in the event-loop front end (see [`ServerConfig::max_wbuf_bytes`]).
/// Far above any sane pipeline depth, low enough that a reader that
/// never drains cannot grow the buffer toward OOM.
pub const DEFAULT_MAX_WBUF_BYTES: usize = 8 << 20;

/// Resource limits for one server (both front ends share this type).
/// The defaults are generous enough to never trip in normal operation
/// while still bounding every resource a misbehaving client could
/// otherwise grow without limit.
#[derive(Clone)]
pub struct ServerConfig {
    /// Maximum concurrently served connections. Connection number
    /// `max_conns + 1` receives a `too-many-connections` error line and
    /// is closed without being served.
    pub max_conns: usize,
    /// Close a connection whose peer sends nothing for this long.
    /// `None` (the default) waits forever, matching the pre-limit
    /// behavior.
    pub read_timeout: Option<Duration>,
    /// Maximum size in bytes of one request frame — a v1 line or a v2
    /// binary frame; both wires enforce the same cap. Larger frames get
    /// a `request-too-large` error and the connection is closed.
    pub max_frame_bytes: usize,
    /// Dispatch worker threads for the event-loop front end (`0` sizes
    /// from `available_parallelism`). The thread-per-connection server
    /// ignores this — its parallelism is its connection count.
    pub dispatch_threads: usize,
    /// Event-loop front end only: maximum bytes of encoded responses
    /// buffered for one connection awaiting the peer's reads. A
    /// pipelining client that never reads would otherwise grow the
    /// buffer without bound (slow-reader OOM); past the cap the
    /// connection is closed and `conns_overflowed` counts it. The
    /// threaded front end has no such buffer — its writes block per
    /// response.
    pub max_wbuf_bytes: usize,
    /// Observer for the dispatch seam (`repro record` installs one to
    /// capture session traces); `None` costs nothing.
    pub tap: Option<Arc<dyn DispatchTap>>,
    /// Event-loop front end only: maximum requests queued for the
    /// dispatch workers before new requests are shed with a structured
    /// `overloaded` error (the connection stays open). `0` (the
    /// default) keeps the queue unbounded, matching the pre-overload
    /// behavior. The threaded front end has no dispatch queue — each
    /// connection's thread is its own backpressure — so it ignores this.
    pub max_queue_depth: usize,
    /// Event-loop front end only: maximum in-flight (dispatched but not
    /// yet flushed) requests per connection; past it new requests on
    /// that connection are shed with `overloaded`. `0` = unbounded.
    pub max_inflight: usize,
    /// Deterministic fault injection plane (`repro serve --fault-spec`);
    /// `None` injects nothing and costs nothing.
    pub faults: Option<Arc<FaultPlane>>,
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig {
            max_conns: 1024,
            read_timeout: None,
            max_frame_bytes: DEFAULT_MAX_FRAME_BYTES,
            dispatch_threads: 0,
            max_wbuf_bytes: DEFAULT_MAX_WBUF_BYTES,
            tap: None,
            max_queue_depth: 0,
            max_inflight: 0,
            faults: None,
        }
    }
}

impl std::fmt::Debug for ServerConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ServerConfig")
            .field("max_conns", &self.max_conns)
            .field("read_timeout", &self.read_timeout)
            .field("max_frame_bytes", &self.max_frame_bytes)
            .field("dispatch_threads", &self.dispatch_threads)
            .field("max_wbuf_bytes", &self.max_wbuf_bytes)
            .field("tap", &self.tap.as_ref().map(|_| "installed"))
            .field("max_queue_depth", &self.max_queue_depth)
            .field("max_inflight", &self.max_inflight)
            .field("faults", &self.faults)
            .finish()
    }
}

/// Encode a response for the wire, substituting the structured
/// `internal` error when the response itself cannot be framed (v2's
/// `u32` length ceiling). Responses are deliberately not bounded by the
/// *request* cap — a snapshot legitimately exceeds it — so the only
/// limit here is structural.
pub(crate) fn encode_response_or_error(wire: Wire, resp: &Response) -> Vec<u8> {
    try_encode_response(wire, resp, MAX_V2_PAYLOAD_BYTES)
        .unwrap_or_else(|e| encode_error(wire, &e))
}

/// [`dispatch_tapped`] hardened for a server front end: a panic inside
/// the request handler (a buggy policy, a broken invariant) is contained
/// to a structured `internal` error response instead of unwinding
/// through the connection handler or dispatch worker — one poisonous
/// request must not take the server (or its shared locks) down with it.
/// Also the injection point for the `stall` fault (the service seam).
/// Both front ends funnel through here, keeping their semantics aligned.
pub(crate) fn dispatch_contained(
    req: Request,
    client: &Client,
    counters: &ConnCounters,
    tap: Option<&Arc<dyn DispatchTap>>,
    faults: Option<&Arc<FaultPlane>>,
) -> Dispatched {
    if let Some(f) = faults {
        f.maybe_stall();
    }
    std::panic::catch_unwind(AssertUnwindSafe(|| dispatch_tapped(req, client, counters, tap)))
    .unwrap_or_else(|_| {
        Dispatched::Error(WireError::new(
            ErrorCode::Internal,
            "request handler panicked; the request may not have been applied".to_string(),
        ))
    })
}

/// A running TCP front end over a coordinator `Client`.
pub struct Server {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_handle: Option<std::thread::JoinHandle<()>>,
    /// Live connections: the stream (so `stop()` can unblock a reader
    /// with `shutdown`) and the handler thread (so `stop()` can join
    /// it). The accept loop prunes finished entries as it goes.
    conns: Arc<Mutex<Vec<(TcpStream, std::thread::JoinHandle<()>)>>>,
    counters: Arc<ConnCounters>,
}

impl Server {
    /// Bind `addr` (use port 0 for ephemeral) and serve with default
    /// limits until `stop()`.
    pub fn start(addr: &str, client: Client) -> Result<Server> {
        Server::start_with_config(addr, client, ServerConfig::default())
    }

    /// Bind `addr` and serve with explicit resource limits.
    pub fn start_with_config(addr: &str, client: Client, cfg: ServerConfig) -> Result<Server> {
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<(TcpStream, std::thread::JoinHandle<()>)>>> =
            Arc::new(Mutex::new(Vec::new()));
        let counters = Arc::new(ConnCounters::default());
        let counters_ret = counters.clone();
        let cfg = Arc::new(cfg);
        let stop2 = stop.clone();
        let conns2 = conns.clone();
        let handle = std::thread::Builder::new()
            .name("ksplus-server-accept".into())
            .spawn(move || {
                for conn in listener.incoming() {
                    if stop2.load(Ordering::SeqCst) {
                        break;
                    }
                    let stream = match conn {
                        Ok(s) => s,
                        Err(_) => break,
                    };
                    let mut guard = conns2.lock().unwrap();
                    // Reap connections that already finished; their
                    // joins are instant.
                    let mut i = 0;
                    while i < guard.len() {
                        if guard[i].1.is_finished() {
                            let (_, h) = guard.swap_remove(i);
                            let _ = h.join();
                        } else {
                            i += 1;
                        }
                    }
                    if guard.len() >= cfg.max_conns {
                        counters.refused.fetch_add(1, Ordering::Relaxed);
                        let mut stream = stream;
                        let err = WireError::new(
                            ErrorCode::TooManyConnections,
                            format!("server is at its limit of {} connections", cfg.max_conns),
                        );
                        // Refused before negotiation, so v1 by definition.
                        let _ = stream.write_all(&encode_error(Wire::V1, &err));
                        continue; // dropping `stream` closes it
                    }
                    let c = client.clone();
                    let cfg_c = cfg.clone();
                    let counters_c = counters.clone();
                    let tracked = match stream.try_clone() {
                        Ok(t) => t,
                        Err(_) => continue,
                    };
                    let h = std::thread::spawn(move || {
                        let _ = handle_conn(stream, c, &cfg_c, &counters_c);
                    });
                    guard.push((tracked, h));
                }
            })?;
        Ok(Server {
            addr: local,
            stop,
            accept_handle: Some(handle),
            conns,
            counters: counters_ret,
        })
    }

    /// Build a coordinator pool and a server over it in one call. Backend
    /// construction failures (e.g. a PJRT spec in a native-only build)
    /// surface as `Err` here, before anything is bound or detached.
    pub fn start_with_backend(
        addr: &str,
        cfg: CoordinatorConfig,
        spec: BackendSpec,
    ) -> Result<(Coordinator, Server)> {
        let coord = Coordinator::start(cfg, spec).context("start coordinator")?;
        let server = Server::start(addr, coord.client())?;
        Ok((coord, server))
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// This front end's connection counters (shared with every handler).
    pub fn counters(&self) -> Arc<ConnCounters> {
        self.counters.clone()
    }

    /// Stop accepting, then unblock and join every live connection
    /// handler.
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Unblock accept() with a throwaway connection. A listener bound
        // to an unspecified address (0.0.0.0 / [::]) is reached through
        // the loopback of the same family instead — several platforms
        // refuse connects to the unspecified address, which would leave
        // accept() blocked forever.
        let target = if self.addr.ip().is_unspecified() {
            let ip: std::net::IpAddr = match self.addr.ip() {
                std::net::IpAddr::V4(_) => std::net::Ipv4Addr::LOCALHOST.into(),
                std::net::IpAddr::V6(_) => std::net::Ipv6Addr::LOCALHOST.into(),
            };
            std::net::SocketAddr::new(ip, self.addr.port())
        } else {
            self.addr
        };
        let _ = TcpStream::connect(target);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
        // With the accept loop gone, no new connections appear. Shut
        // every live stream down — a handler blocked in a read sees EOF
        // and returns — then join them all.
        let drained: Vec<_> = {
            let mut guard = self.conns.lock().unwrap();
            std::mem::take(&mut *guard)
        };
        for (stream, handle) in drained {
            let _ = stream.shutdown(Shutdown::Both);
            let _ = handle.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

fn handle_conn(
    stream: TcpStream,
    client: Client,
    cfg: &ServerConfig,
    counters: &ConnCounters,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(cfg.read_timeout).ok();
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    // Every connection starts on wire v1; a successful `hello`
    // negotiation may switch it (STARTTLS-style: the hello response
    // still travels on the wire the hello arrived on).
    let mut wire = Wire::V1;
    loop {
        match read_frame(&mut reader, wire, cfg.max_frame_bytes)? {
            FrameRead::Eof => return Ok(()),
            FrameRead::TimedOut => {
                counters.timeouts.fetch_add(1, Ordering::Relaxed);
                return Ok(());
            }
            FrameRead::TooLong => {
                let err = WireError::new(
                    ErrorCode::RequestTooLarge,
                    format!(
                        "request exceeds the {}-byte limit; closing connection",
                        cfg.max_frame_bytes
                    ),
                );
                writer.write_all(&encode_error(wire, &err))?;
                return Ok(());
            }
            FrameRead::Frame(payload) => match decode_request(wire, &payload) {
                Ok(None) => continue, // blank v1 line: no reply
                Ok(Some(req)) => {
                    match dispatch_contained(
                        req,
                        &client,
                        counters,
                        cfg.tap.as_ref(),
                        cfg.faults.as_ref(),
                    ) {
                        Dispatched::Reply(resp) => {
                            writer.write_all(&encode_response_or_error(wire, &resp))?;
                        }
                        Dispatched::Error(err) => {
                            writer.write_all(&encode_error(wire, &err))?;
                        }
                        Dispatched::Hello(resp, version) => {
                            writer.write_all(&encode_response_or_error(wire, &resp))?;
                            if let Some(w) = Wire::from_version(version) {
                                wire = w;
                            }
                        }
                    }
                }
                Err(e) => writer.write_all(&encode_error(wire, &e))?,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::protocol::{Request, Response, OPS, WIRE_V2, WIRE_VERSION};
    use crate::coordinator::service::{Coordinator, CoordinatorConfig};
    use crate::coordinator::wire::try_encode_request;
    use crate::coordinator::{BackendSpec, PredictorPolicy};
    use crate::util::json::Json;
    use crate::util::rng::Rng;
    use std::io::BufRead;

    fn start() -> (Coordinator, Server) {
        Server::start_with_backend(
            "127.0.0.1:0",
            CoordinatorConfig { k: 2, ..Default::default() },
            BackendSpec::Native,
        )
        .unwrap()
    }

    fn start_cfg(cfg: ServerConfig) -> (Coordinator, Server) {
        let coord = Coordinator::start(
            CoordinatorConfig { k: 2, ..Default::default() },
            BackendSpec::Native,
        )
        .unwrap();
        let server = Server::start_with_config("127.0.0.1:0", coord.client(), cfg).unwrap();
        (coord, server)
    }

    fn roundtrip(stream: &mut TcpStream, req: &str) -> Json {
        writeln!(stream, "{req}").unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        Json::parse(&line).unwrap()
    }

    fn train_req() -> String {
        let mut rng = Rng::new(1);
        let mut hist = Vec::new();
        for _ in 0..12 {
            let input = rng.uniform(2000.0, 10000.0);
            let n = ((input * 0.005) as usize).max(3);
            let samples: Vec<String> = (0..n)
                .map(|i| {
                    let lvl = if i < n / 2 { input * 0.0004 } else { input * 0.0009 };
                    format!("{:.4}", lvl)
                })
                .collect();
            hist.push(format!(
                r#"{{"input_mb":{input:.1},"dt":1.0,"samples":[{}]}}"#,
                samples.join(",")
            ));
        }
        format!(r#"{{"op":"train","task":"bwa","history":[{}]}}"#, hist.join(","))
    }

    #[test]
    fn train_plan_failure_roundtrip() {
        let (_coord, server) = start();
        let mut s = TcpStream::connect(server.addr()).unwrap();
        let r = roundtrip(&mut s, &train_req());
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(r.get("executions").and_then(Json::as_usize), Some(12));

        let r = roundtrip(&mut s, r#"{"op":"plan","task":"bwa","input_mb":6000}"#);
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
        let plan = r.get("plan").unwrap();
        let starts = plan.get("starts").unwrap().as_arr().unwrap();
        assert!(!starts.is_empty());
        // Provenance: a trained KS+ plan says so.
        assert_eq!(r.get("predictor").and_then(Json::as_str), Some("ksplus"));
        assert_eq!(r.get("model_version").and_then(Json::as_usize), Some(12));
        assert!(r.get("fallback_reason").is_none());

        let fail = format!(
            r#"{{"op":"failure","plan":{plan},"fail_time":5.0}}"#,
            plan = plan
        );
        let r = roundtrip(&mut s, &fail);
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(r.get("predictor").and_then(Json::as_str), Some("ksplus"));

        let r = roundtrip(&mut s, r#"{"op":"stats"}"#);
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(r.get("tasks_trained").and_then(Json::as_usize), Some(1));
        assert_eq!(r.get("fallbacks").and_then(Json::as_usize), Some(0));
    }

    #[test]
    fn hello_negotiates_and_advertises() {
        let (_coord, server) = start();
        let mut s = TcpStream::connect(server.addr()).unwrap();
        let r = roundtrip(&mut s, r#"{"op":"hello","client":"t","min_version":1}"#);
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(r.get("version").and_then(Json::as_usize), Some(WIRE_VERSION));
        let ops = r.get("ops").unwrap().as_arr().unwrap();
        assert_eq!(ops.len(), OPS.len());
        for op in OPS {
            assert!(ops.iter().any(|o| o.as_str() == Some(op)), "missing op {op}");
        }
        // The admin ops ride the capability list, so a cautious client
        // can feature-detect them before use.
        for admin in ["snapshot", "reshard"] {
            assert!(ops.iter().any(|o| o.as_str() == Some(admin)), "missing {admin}");
        }
        let policies = r.get("policies").unwrap().as_arr().unwrap();
        for p in PredictorPolicy::names() {
            assert!(policies.iter().any(|x| x.as_str() == Some(p)), "missing policy {p}");
        }
        // A client from the future is refused with the specific code.
        let r = roundtrip(&mut s, r#"{"op":"hello","min_version":99}"#);
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
        assert_eq!(
            r.get("error").and_then(|e| e.get("code")).and_then(Json::as_str),
            Some("unsupported-version")
        );
        // A client from the past likewise.
        let r = roundtrip(&mut s, r#"{"op":"hello","max_version":0}"#);
        assert_eq!(
            r.get("error").and_then(|e| e.get("code")).and_then(Json::as_str),
            Some("unsupported-version")
        );
    }

    #[test]
    fn threaded_server_negotiates_and_serves_wire_v2() {
        let (_coord, server) = start();
        let mut s = TcpStream::connect(server.addr()).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        // The hello rides v1; its response is still v1 JSON, and only
        // then does the connection switch to binary framing.
        let r = roundtrip(&mut s, r#"{"op":"hello","min_version":1,"max_version":2}"#);
        assert_eq!(r.get("version").and_then(Json::as_usize), Some(WIRE_V2));

        let mut reader = BufReader::new(s.try_clone().unwrap());
        let req = Request::Plan { task: "fresh".into(), input_mb: 64.0 };
        s.write_all(&try_encode_request(Wire::V2, &req, DEFAULT_MAX_FRAME_BYTES).unwrap())
            .unwrap();
        match read_frame(&mut reader, Wire::V2, DEFAULT_MAX_FRAME_BYTES).unwrap() {
            FrameRead::Frame(payload) => {
                let resp =
                    crate::coordinator::wire::decode_response(Wire::V2, &payload, "plan")
                        .expect("plan should succeed");
                match resp {
                    Response::Planned(o) => {
                        assert_eq!(o.predictor, "default-limits");
                        assert!(o.plan.is_valid());
                    }
                    other => panic!("unexpected response: {other:?}"),
                }
            }
            other => panic!("expected a binary frame, got {other:?}"),
        }
    }

    #[test]
    fn configure_switches_policy_and_plan_reports_provenance() {
        let (_coord, server) = start();
        let mut s = TcpStream::connect(server.addr()).unwrap();
        let r = roundtrip(&mut s, r#"{"op":"configure","task":"bwa","policy":"witt-lr"}"#);
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(r.get("configured").and_then(Json::as_str), Some("bwa"));
        assert_eq!(r.get("policy").and_then(Json::as_str), Some("witt-lr"));
        let r = roundtrip(&mut s, &train_req());
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
        let r = roundtrip(&mut s, r#"{"op":"plan","task":"bwa","input_mb":6000}"#);
        assert_eq!(r.get("predictor").and_then(Json::as_str), Some("witt-lr"));
        assert_eq!(
            r.get("plan").unwrap().get("starts").unwrap().as_arr().unwrap().len(),
            1,
            "witt serves flat plans"
        );
        // Untrained task: fallback provenance + counted in stats.
        let r = roundtrip(&mut s, r#"{"op":"plan","task":"mystery","input_mb":10}"#);
        assert_eq!(r.get("predictor").and_then(Json::as_str), Some("default-limits"));
        assert_eq!(
            r.get("fallback_reason").and_then(Json::as_str),
            Some("untrained-task")
        );
        let r = roundtrip(&mut s, r#"{"op":"stats"}"#);
        assert_eq!(r.get("fallbacks").and_then(Json::as_usize), Some(1));
        // Service-wide default via task-less configure.
        let r = roundtrip(&mut s, r#"{"op":"configure","policy":"tovar-ppm"}"#);
        assert_eq!(r.get("configured").and_then(Json::as_str), Some("*"));
    }

    #[test]
    fn observe_streams_one_execution_at_a_time() {
        let (_coord, server) = start();
        let mut s = TcpStream::connect(server.addr()).unwrap();
        for i in 0..3usize {
            let r = roundtrip(
                &mut s,
                &format!(
                    r#"{{"op":"observe","task":"bwa","execution":{{"input_mb":{},"dt":1.0,"samples":[1.0,1.2,{:.1}]}}}}"#,
                    4000 + i * 1000,
                    2.0 + i as f64
                ),
            );
            assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
            assert_eq!(r.get("observed").and_then(Json::as_str), Some("bwa"));
            assert_eq!(r.get("executions").and_then(Json::as_usize), Some(i + 1));
            assert_eq!(r.get("predictor").and_then(Json::as_str), Some("ksplus"));
        }
        let r = roundtrip(&mut s, r#"{"op":"plan","task":"bwa","input_mb":5000}"#);
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
        let r = roundtrip(&mut s, r#"{"op":"stats"}"#);
        assert_eq!(r.get("observations").and_then(Json::as_usize), Some(3));
        assert_eq!(r.get("tasks_trained").and_then(Json::as_usize), Some(0));
    }

    #[test]
    fn observe_op_equals_train_op() {
        // The same history, once as a batch `train` and once streamed
        // through `observe`, must yield identical plans (both paths are
        // native f64 sufficient statistics).
        let (_c1, trained) = start();
        let (_c2, observed) = start();
        let mut st = TcpStream::connect(trained.addr()).unwrap();
        let mut so = TcpStream::connect(observed.addr()).unwrap();
        let r = roundtrip(&mut st, &train_req());
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
        // Stream the identical executions one by one.
        let req = Json::parse(&train_req()).unwrap();
        for e in req.get("history").unwrap().as_arr().unwrap() {
            let r = roundtrip(
                &mut so,
                &format!(r#"{{"op":"observe","task":"bwa","execution":{e}}}"#),
            );
            assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
        }
        for input in [2500, 6000, 9500] {
            let a = roundtrip(&mut st, &format!(r#"{{"op":"plan","task":"bwa","input_mb":{input}}}"#));
            let b = roundtrip(&mut so, &format!(r#"{{"op":"plan","task":"bwa","input_mb":{input}}}"#));
            assert_eq!(a.get("plan"), b.get("plan"), "input {input}");
        }
    }

    #[test]
    fn malformed_requests_get_errors_not_disconnects() {
        let (_coord, server) = start();
        let mut s = TcpStream::connect(server.addr()).unwrap();
        for bad in [
            "not json",
            r#"{"op":"frobnicate"}"#,
            r#"{"op":"plan"}"#,
            r#"{"op":"train","task":"x","history":[]}"#,
            r#"{"op":"failure","plan":{"starts":[],"peaks":[]},"fail_time":1}"#,
            r#"{"op":"observe","task":"x"}"#,
            r#"{"op":"observe","task":"x","execution":{"input_mb":1,"dt":1.0,"samples":[]}}"#,
            r#"{"op":"observe","task":"x","execution":{"input_mb":1,"dt":0,"samples":[1.0]}}"#,
            r#"{"op":"configure","task":"x","policy":"nope"}"#,
            r#"{"op":"reshard"}"#,
            r#"{"op":"reshard","shards":0}"#,
        ] {
            let r = roundtrip(&mut s, bad);
            assert_eq!(r.get("ok"), Some(&Json::Bool(false)), "req: {bad}");
            // Structured: every error carries a code and a message.
            let err = r.get("error").expect("missing error object");
            assert!(err.get("code").and_then(Json::as_str).is_some(), "req: {bad}");
            assert!(err.get("message").and_then(Json::as_str).is_some(), "req: {bad}");
        }
        // Connection still usable afterwards.
        let r = roundtrip(&mut s, r#"{"op":"stats"}"#);
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
    }

    #[test]
    fn oversized_request_line_gets_error_then_close() {
        // Regression for the unbounded `reader.lines()` read path: a
        // frame past the configured cap must produce a structured
        // `request-too-large` error and a closed connection, not an
        // unbounded allocation.
        let cfg = ServerConfig { max_frame_bytes: 4096, ..Default::default() };
        let (_coord, server) = start_cfg(cfg);
        let mut s = TcpStream::connect(server.addr()).unwrap();
        let huge = format!(
            r#"{{"op":"plan","task":"{}","input_mb":1}}"#,
            "x".repeat(16 * 1024)
        );
        writeln!(s, "{huge}").unwrap();
        let mut reader = BufReader::new(s.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let r = Json::parse(&line).unwrap();
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
        assert_eq!(
            r.get("error").and_then(|e| e.get("code")).and_then(Json::as_str),
            Some("request-too-large")
        );
        // The connection is closed after the error (EOF, or a reset —
        // the unread remainder of the frame may elicit an RST on some
        // platforms).
        line.clear();
        let n = reader.read_line(&mut line).unwrap_or(0);
        assert_eq!(n, 0, "connection must be closed after request-too-large");

        // A fresh connection under the cap is served normally.
        let mut s2 = TcpStream::connect(server.addr()).unwrap();
        let r = roundtrip(&mut s2, r#"{"op":"stats"}"#);
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
    }

    #[test]
    fn connection_limit_refuses_with_wire_error_and_counts_it() {
        let cfg = ServerConfig { max_conns: 2, ..Default::default() };
        let (_coord, server) = start_cfg(cfg);
        // Fill both slots, proving each is registered by serving a
        // request on it before opening the next.
        let mut s1 = TcpStream::connect(server.addr()).unwrap();
        assert_eq!(roundtrip(&mut s1, r#"{"op":"stats"}"#).get("ok"), Some(&Json::Bool(true)));
        let mut s2 = TcpStream::connect(server.addr()).unwrap();
        assert_eq!(roundtrip(&mut s2, r#"{"op":"stats"}"#).get("ok"), Some(&Json::Bool(true)));
        // The third connection is refused with the structured error...
        let s3 = TcpStream::connect(server.addr()).unwrap();
        let mut reader = BufReader::new(s3);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let r = Json::parse(&line).unwrap();
        assert_eq!(
            r.get("error").and_then(|e| e.get("code")).and_then(Json::as_str),
            Some("too-many-connections")
        );
        // ...and then closed.
        line.clear();
        let n = reader.read_line(&mut line).unwrap_or(0);
        assert_eq!(n, 0);
        // The refusal shows up in stats served to surviving connections.
        let r = roundtrip(&mut s1, r#"{"op":"stats"}"#);
        assert_eq!(r.get("conns_refused").and_then(Json::as_usize), Some(1));
        // Freeing a slot admits new connections again (the accept loop
        // reaps finished handlers before counting).
        drop(s2);
        std::thread::sleep(Duration::from_millis(50));
        let mut s4 = TcpStream::connect(server.addr()).unwrap();
        let r = roundtrip(&mut s4, r#"{"op":"stats"}"#);
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
    }

    #[test]
    fn idle_connection_is_closed_and_counted() {
        let cfg = ServerConfig {
            read_timeout: Some(Duration::from_millis(80)),
            ..Default::default()
        };
        let (_coord, server) = start_cfg(cfg);
        let mut s = TcpStream::connect(server.addr()).unwrap();
        // The connection works while active...
        let r = roundtrip(&mut s, r#"{"op":"stats"}"#);
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(r.get("conn_timeouts").and_then(Json::as_usize), Some(0));
        // ...then goes idle past the timeout: the server closes it.
        let mut reader = BufReader::new(s.try_clone().unwrap());
        let mut line = String::new();
        let n = reader.read_line(&mut line).unwrap_or(0);
        assert_eq!(n, 0, "idle connection must be closed by the server");
        // A fresh connection sees the timeout counted.
        let mut s2 = TcpStream::connect(server.addr()).unwrap();
        let r = roundtrip(&mut s2, r#"{"op":"stats"}"#);
        assert_eq!(r.get("conn_timeouts").and_then(Json::as_usize), Some(1));
    }

    #[test]
    fn snapshot_and_reshard_over_the_wire() {
        let (_coord, server) = start();
        let mut s = TcpStream::connect(server.addr()).unwrap();
        roundtrip(&mut s, &train_req());
        let before = roundtrip(&mut s, r#"{"op":"plan","task":"bwa","input_mb":6000}"#);
        assert_eq!(before.get("ok"), Some(&Json::Bool(true)));

        // Snapshot returns a restorable document.
        let r = roundtrip(&mut s, r#"{"op":"snapshot"}"#);
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
        let doc = r.get("snapshot").expect("missing snapshot payload");
        assert!(doc.get("schema").and_then(Json::as_str).is_some());
        assert!(doc
            .get("tasks")
            .and_then(Json::as_arr)
            .map(|t| !t.is_empty())
            .unwrap_or(false));

        // Reshard to 3 workers; hello and stats agree on the new width,
        // and the trained task plans bit-identically afterwards.
        let r = roundtrip(&mut s, r#"{"op":"reshard","shards":3}"#);
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
        assert_eq!(r.get("shard_ids").and_then(Json::as_arr).map(Vec::len), Some(3));
        let r = roundtrip(&mut s, r#"{"op":"stats"}"#);
        assert_eq!(r.get("shards").and_then(Json::as_usize), Some(3));
        let after = roundtrip(&mut s, r#"{"op":"plan","task":"bwa","input_mb":6000}"#);
        assert_eq!(before.get("plan"), after.get("plan"));
        assert_eq!(before.get("model_version"), after.get("model_version"));

        // Out-of-range widths are rejected with invalid-field.
        let r = roundtrip(&mut s, r#"{"op":"reshard","shards":100000}"#);
        assert_eq!(
            r.get("error").and_then(|e| e.get("code")).and_then(Json::as_str),
            Some("invalid-field")
        );
    }

    #[test]
    fn concurrent_connections_share_batcher() {
        let (coord, server) = start();
        let mut s0 = TcpStream::connect(server.addr()).unwrap();
        roundtrip(&mut s0, &train_req());
        let mut handles = Vec::new();
        for i in 0..8 {
            let addr = server.addr();
            handles.push(std::thread::spawn(move || {
                let mut s = TcpStream::connect(addr).unwrap();
                for j in 0..10 {
                    let r = roundtrip(
                        &mut s,
                        &format!(
                            r#"{{"op":"plan","task":"bwa","input_mb":{}}}"#,
                            3000 + i * 100 + j
                        ),
                    );
                    assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let stats = coord.client().stats();
        assert_eq!(stats.requests, 80);
        assert!(stats.batches <= 80);
    }

    /// Tap that panics on a chosen task name — stands in for any buggy
    /// handler-side code (a policy, a recorder) blowing up mid-request.
    struct PanickingTap;
    impl DispatchTap for PanickingTap {
        fn observe(&self, req: &Request, _out: &Dispatched) {
            if let Request::Plan { task, .. } = req {
                if task == "boom" {
                    panic!("tap exploded");
                }
            }
        }
    }

    #[test]
    fn handler_panic_is_contained_to_an_internal_error() {
        let cfg = ServerConfig { tap: Some(Arc::new(PanickingTap)), ..Default::default() };
        let (_coord, server) = start_cfg(cfg);
        let mut s = TcpStream::connect(server.addr()).unwrap();
        let r = roundtrip(&mut s, r#"{"op":"plan","task":"boom","input_mb":10}"#);
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
        assert_eq!(
            r.get("error").and_then(|e| e.get("code")).and_then(Json::as_str),
            Some("internal")
        );
        // The same connection keeps serving — and so does the shared
        // coordinator state the panicking thread touched.
        let r = roundtrip(&mut s, r#"{"op":"plan","task":"fine","input_mb":10}"#);
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
        let r = roundtrip(&mut s, r#"{"op":"stats"}"#);
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
        // Both plans reached the coordinator (the tap panics after
        // dispatch); the panic cost nothing but its own request's reply.
        assert_eq!(r.get("requests").and_then(Json::as_usize), Some(2));
    }

    #[test]
    fn stop_unblocks_accept() {
        let (_coord, mut server) = start();
        server.stop(); // must not hang
    }

    #[test]
    fn stop_joins_live_connections() {
        // A connection sitting idle in a blocking read (no read timeout
        // configured) must not wedge `stop()`: the server shuts the
        // stream down and joins the handler.
        let (_coord, mut server) = start();
        let mut s = TcpStream::connect(server.addr()).unwrap();
        let r = roundtrip(&mut s, r#"{"op":"stats"}"#);
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
        server.stop(); // must not hang with `s` still open and idle
        // The server side of the connection is gone.
        let mut reader = BufReader::new(s);
        let mut line = String::new();
        let n = reader.read_line(&mut line).unwrap_or(0);
        assert_eq!(n, 0);
    }

    #[test]
    fn stop_unblocks_accept_on_unspecified_bind() {
        // Binding to 0.0.0.0 must still stop cleanly: the unblocking
        // connect goes to loopback, not to the unspecified address.
        let coord =
            Coordinator::start(CoordinatorConfig::default(), BackendSpec::Native).unwrap();
        let mut server = Server::start("0.0.0.0:0", coord.client()).unwrap();
        assert!(server.addr().ip().is_unspecified());
        // The server is reachable through loopback before the stop.
        let mut s =
            TcpStream::connect(("127.0.0.1", server.addr().port())).unwrap();
        let r = roundtrip(&mut s, r#"{"op":"stats"}"#);
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
        server.stop(); // must not hang
    }

    #[test]
    fn stats_reports_shard_count() {
        let (_coord, server) = Server::start_with_backend(
            "127.0.0.1:0",
            CoordinatorConfig { k: 2, shards: 3, ..Default::default() },
            BackendSpec::Native,
        )
        .unwrap();
        let mut s = TcpStream::connect(server.addr()).unwrap();
        let r = roundtrip(&mut s, r#"{"op":"stats"}"#);
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(r.get("shards").and_then(Json::as_usize), Some(3));
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn backend_build_error_propagates_through_server_start() {
        // The startup seam end-to-end: an unbuildable backend spec fails
        // the combined constructor before any socket is bound, instead of
        // panicking a detached worker thread.
        let err = Server::start_with_backend(
            "127.0.0.1:0",
            CoordinatorConfig::default(),
            BackendSpec::Pjrt(None),
        )
        .err()
        .expect("pjrt spec must not serve in a native-only build");
        let msg = format!("{err:#}");
        assert!(msg.contains("pjrt"), "unhelpful error: {msg}");
    }
}
