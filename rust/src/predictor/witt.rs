//! Witt et al. linear-regression peak predictors [14], [15]: peak memory
//! as a linear function of input size plus an offset strategy, with a
//! doubling retry. Implemented as extension baselines (related work).

use crate::predictor::regression::LinModel;
use crate::predictor::Predictor;
use crate::segments::StepPlan;
use crate::trace::Execution;
use crate::util::stats;

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Offset {
    /// LR mean +- : add one standard deviation of the residuals.
    MeanSigma,
    /// LR max: add the largest observed underprediction.
    MaxUnder,
}

pub struct WittLr {
    capacity: f64,
    offset_mode: Offset,
    model: Option<LinModel>,
    offset: f64,
    fallback_peak: f64,
}

impl WittLr {
    pub fn new(capacity: f64, offset_mode: Offset) -> Self {
        WittLr { capacity, offset_mode, model: None, offset: 0.0, fallback_peak: 2.0 }
    }
}

impl Predictor for WittLr {
    fn name(&self) -> &'static str {
        match self.offset_mode {
            Offset::MeanSigma => "witt-lr-mean",
            Offset::MaxUnder => "witt-lr-max",
        }
    }

    fn train(&mut self, history: &[Execution]) {
        if history.is_empty() {
            self.model = None;
            return;
        }
        let xs: Vec<f64> = history.iter().map(|e| e.input_mb).collect();
        let ys: Vec<f64> = history.iter().map(|e| e.peak()).collect();
        let m = LinModel::fit(&xs, &ys);
        let resid = stats::residuals(&xs, &ys, m.slope, m.intercept);
        self.offset = match self.offset_mode {
            Offset::MeanSigma => stats::stddev(&resid),
            // Largest underprediction: max positive residual (actual
            // above prediction), zero if the model never underpredicts.
            Offset::MaxUnder => resid.iter().cloned().fold(0.0, f64::max),
        };
        self.model = Some(m);
        self.fallback_peak = ys.iter().cloned().fold(0.0, f64::max).max(0.1);
    }

    fn plan(&self, input_mb: f64) -> StepPlan {
        let Some(m) = self.model else {
            return StepPlan::flat(self.fallback_peak.min(self.capacity));
        };
        let peak = (m.predict(input_mb) + self.offset).max(1e-3);
        StepPlan::flat(peak.min(self.capacity))
    }

    fn on_failure(&self, prev: &StepPlan, _fail_time: f64, _attempt: usize) -> StepPlan {
        let prev_peak = prev.last_peak_or(self.fallback_peak);
        StepPlan::flat((prev_peak * 2.0).min(self.capacity))
    }

    fn capacity(&self) -> f64 {
        self.capacity
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn hist(rng: &mut Rng, n: usize, noise: f64) -> Vec<Execution> {
        (0..n)
            .map(|_| {
                let input = rng.uniform(1000.0, 9000.0);
                let p = 0.001 * input + 1.0 + rng.normal_ms(0.0, noise);
                Execution::new("t", input, 1.0, vec![p.max(0.1)])
            })
            .collect()
    }

    #[test]
    fn recovers_linear_relation() {
        let mut rng = Rng::new(1);
        let mut p = WittLr::new(128.0, Offset::MeanSigma);
        p.train(&hist(&mut rng, 100, 0.0));
        // noise-free: offset ~0, prediction ~exact
        let plan = p.plan(5000.0);
        assert!((plan.peaks[0] - 6.0).abs() < 0.1, "{:?}", plan.peaks);
    }

    #[test]
    fn max_under_offset_covers_training_set() {
        let mut rng = Rng::new(2);
        let h = hist(&mut rng, 80, 0.4);
        let mut p = WittLr::new(128.0, Offset::MaxUnder);
        p.train(&h);
        // By construction every training execution is covered.
        for e in &h {
            assert!(
                p.plan(e.input_mb).peaks[0] + 1e-9 >= e.peak(),
                "training execution not covered"
            );
        }
    }

    #[test]
    fn mean_sigma_offset_positive_with_noise() {
        let mut rng = Rng::new(3);
        let mut p = WittLr::new(128.0, Offset::MeanSigma);
        p.train(&hist(&mut rng, 80, 0.5));
        let noiseless_pred = 0.001 * 5000.0 + 1.0;
        assert!(p.plan(5000.0).peaks[0] > noiseless_pred, "offset not applied");
    }

    #[test]
    fn retry_doubles_and_clamps() {
        let p = WittLr::new(128.0, Offset::MeanSigma);
        assert_eq!(p.on_failure(&StepPlan::flat(5.0), 1.0, 1), StepPlan::flat(10.0));
        assert_eq!(p.on_failure(&StepPlan::flat(90.0), 1.0, 1), StepPlan::flat(128.0));
    }

    #[test]
    fn untrained_fallback_flat() {
        let p = WittLr::new(128.0, Offset::MaxUnder);
        assert_eq!(p.plan(100.0).k(), 1);
    }
}
