//! Importer for nf-core/Nextflow-style monitoring exports.
//!
//! Real deployments record one row per monitoring sample (the format the
//! original k-Segments dataset uses): long-form CSV
//!
//! ```text
//! process,task_id,input_bytes,timestamp_ms,rss_bytes
//! BWA_ALIGN,17,8388608000,1000,5476083712
//! BWA_ALIGN,17,8388608000,3000,5478180864
//! ...
//! ```
//!
//! Rows may be unsorted and interleaved across task ids; timestamps are
//! absolute milliseconds. This module groups rows by (process, task_id),
//! sorts by timestamp, resamples to the per-execution median interval,
//! and emits the crate's `Execution` type (memory GB, input MB).

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::trace::{Execution, TaskTraces, WorkflowTrace};

pub const HEADER: &str = "process,task_id,input_bytes,timestamp_ms,rss_bytes";

/// Upper bound on the resampled grid per task instance. A long-duration
/// instance whose median gap is tiny (one dense burst of samples inside
/// hours of sparse monitoring) would otherwise ask for a multi-million-
/// sample grid — `Vec::with_capacity` on an adversarial CSV could OOM the
/// importer. Past the cap, `dt` is coarsened to span the instance in
/// exactly this many samples.
pub const MAX_RESAMPLE: usize = 100_000;

#[derive(Debug, Clone, Copy)]
struct Row {
    input_bytes: f64,
    t_ms: f64,
    rss_bytes: f64,
}

/// Parse a long-form monitoring CSV into a `WorkflowTrace`.
pub fn read_long_csv(path: &Path, workflow_name: &str) -> Result<WorkflowTrace> {
    let f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    parse_long_csv(BufReader::new(f), workflow_name)
}

pub fn parse_long_csv<R: BufRead>(reader: R, workflow_name: &str) -> Result<WorkflowTrace> {
    let mut lines = reader.lines();
    match lines.next() {
        Some(Ok(h)) if h.trim() == HEADER => {}
        other => bail!("bad header: expected '{HEADER}', got {other:?}"),
    }
    // (process, task_id) -> rows
    let mut groups: BTreeMap<(String, u64), Vec<Row>> = BTreeMap::new();
    for (lineno, line) in lines.enumerate() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let ctx = || format!("line {}", lineno + 2);
        let mut it = line.split(',');
        let process = it.next().with_context(ctx)?.trim().to_string();
        let task_id: u64 = it.next().with_context(ctx)?.trim().parse().with_context(ctx)?;
        let input_bytes: f64 = it.next().with_context(ctx)?.trim().parse().with_context(ctx)?;
        let t_ms: f64 = it.next().with_context(ctx)?.trim().parse().with_context(ctx)?;
        let rss_bytes: f64 = it.next().with_context(ctx)?.trim().parse().with_context(ctx)?;
        if it.next().is_some() {
            bail!("line {}: too many fields", lineno + 2);
        }
        if !(input_bytes >= 0.0 && rss_bytes >= 0.0) {
            bail!("line {}: negative sizes", lineno + 2);
        }
        groups.entry((process, task_id)).or_default().push(Row {
            input_bytes,
            t_ms,
            rss_bytes,
        });
    }

    let mut trace = WorkflowTrace { name: workflow_name.to_string(), tasks: Vec::new() };
    for ((process, _id), mut rows) in groups {
        rows.sort_by(|a, b| a.t_ms.total_cmp(&b.t_ms));
        let exec = rows_to_execution(&process, &rows)?;
        match trace.tasks.iter_mut().find(|t| t.task == process) {
            Some(t) => t.executions.push(exec),
            None => trace
                .tasks
                .push(TaskTraces { task: process, executions: vec![exec] }),
        }
    }
    Ok(trace)
}

/// Convert one task instance's sorted rows to a fixed-interval series.
fn rows_to_execution(process: &str, rows: &[Row]) -> Result<Execution> {
    anyhow::ensure!(!rows.is_empty(), "empty group");
    let input_mb = rows[0].input_bytes / 1e6;
    if rows.len() == 1 {
        return Ok(Execution::new(process, input_mb, 1.0, vec![rows[0].rss_bytes / 1e9]));
    }
    // Median sampling interval for resampling.
    let mut gaps: Vec<f64> = rows.windows(2).map(|w| w[1].t_ms - w[0].t_ms).collect();
    gaps.retain(|g| *g > 0.0);
    anyhow::ensure!(!gaps.is_empty(), "all timestamps identical for {process}");
    let mut dt_ms = crate::util::stats::median(&gaps);
    let t0 = rows[0].t_ms;
    let t_end = rows[rows.len() - 1].t_ms;
    let mut n = (((t_end - t0) / dt_ms).round() as usize).saturating_add(1);
    let capped = n > MAX_RESAMPLE;
    if capped {
        dt_ms = (t_end - t0) / (MAX_RESAMPLE - 1) as f64;
        n = MAX_RESAMPLE;
        eprintln!(
            "warning: {process}: resample grid capped at {MAX_RESAMPLE} samples \
             (dt coarsened to {dt_ms:.1} ms)"
        );
    }
    // Nearest-earlier sample for each grid point (step interpolation,
    // matching how RSS monitoring behaves).
    let mut samples = Vec::with_capacity(n);
    let mut j = 0usize;
    for i in 0..n {
        let t = t0 + i as f64 * dt_ms;
        while j + 1 < rows.len() && rows[j + 1].t_ms <= t + 1e-9 {
            j += 1;
        }
        samples.push(rows[j].rss_bytes / 1e9);
    }
    if capped {
        // The coarsened dt is no longer an exact multiple of the row
        // gaps, so the last grid point can land a rounding error short of
        // `t_end` and miss the final observation; pin it (the grid ends
        // at `t_end` by construction).
        *samples.last_mut().unwrap() = rows[rows.len() - 1].rss_bytes / 1e9;
    }
    Ok(Execution::new(process, input_mb, dt_ms / 1e3, samples))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn csv(body: &str) -> String {
        format!("{HEADER}\n{body}")
    }

    #[test]
    fn parses_basic_file() {
        let src = csv("BWA,1,8000000000,0,5000000000\n\
                       BWA,1,8000000000,1000,5100000000\n\
                       BWA,1,8000000000,2000,10700000000\n\
                       FASTQC,2,1000000000,0,400000000\n\
                       FASTQC,2,1000000000,1000,450000000\n");
        let t = parse_long_csv(Cursor::new(src), "eager").unwrap();
        assert_eq!(t.tasks.len(), 2);
        let bwa = t.task("BWA").unwrap();
        assert_eq!(bwa.executions.len(), 1);
        let e = &bwa.executions[0];
        assert_eq!(e.samples.len(), 3);
        assert!((e.input_mb - 8000.0).abs() < 1e-9);
        assert!((e.dt - 1.0).abs() < 1e-9);
        assert!((e.peak() - 10.7).abs() < 1e-9);
    }

    #[test]
    fn unsorted_and_interleaved_rows() {
        let src = csv("BWA,1,8e9,2000,3e9\n\
                       BWA,2,4e9,0,1e9\n\
                       BWA,1,8e9,0,1e9\n\
                       BWA,2,4e9,1000,2e9\n\
                       BWA,1,8e9,1000,2e9\n");
        let t = parse_long_csv(Cursor::new(src), "x").unwrap();
        let bwa = t.task("BWA").unwrap();
        assert_eq!(bwa.executions.len(), 2);
        // Instance 1 sorted: 1,2,3 GB.
        let e1 = &bwa.executions[0];
        assert_eq!(e1.samples, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn resamples_irregular_intervals() {
        // Gaps 1s,1s,4s -> median 1s; the 4s hole is filled with the
        // last value (step interpolation).
        let src = csv("T,1,1e9,0,1e9\nT,1,1e9,1000,2e9\nT,1,1e9,2000,3e9\nT,1,1e9,6000,4e9\n");
        let t = parse_long_csv(Cursor::new(src), "x").unwrap();
        let e = &t.task("T").unwrap().executions[0];
        assert_eq!(e.samples.len(), 7);
        assert_eq!(e.samples[3], 3.0); // hole
        assert_eq!(e.samples[6], 4.0);
    }

    #[test]
    fn caps_adversarial_resample_grid() {
        // Three samples 1 ms apart, then one a billion ms later: median
        // gap 1 ms over a 1e9 ms span would resample to a billion-sample
        // grid (and OOM in `Vec::with_capacity`) without the cap.
        let src = csv("T,1,1e9,0,1e9\nT,1,1e9,1,1e9\nT,1,1e9,2,2e9\nT,1,1e9,1000000000,3e9\n");
        let t = parse_long_csv(Cursor::new(src), "x").unwrap();
        let e = &t.task("T").unwrap().executions[0];
        assert_eq!(e.samples.len(), MAX_RESAMPLE);
        assert!((e.peak() - 3.0).abs() < 1e-9);
        // dt was coarsened to span/(MAX_RESAMPLE-1), converted to seconds.
        let want_dt = 1e9 / (MAX_RESAMPLE - 1) as f64 / 1e3;
        assert!((e.dt - want_dt).abs() < 1e-9, "dt {} want {want_dt}", e.dt);
        // Step interpolation still holds: last grid point sees the final
        // sample, earlier points the dense prefix.
        assert_eq!(*e.samples.last().unwrap(), 3.0);
        assert_eq!(e.samples[0], 1.0);
    }

    #[test]
    fn single_sample_instance() {
        let src = csv("T,1,5e8,1000,2e9\n");
        let t = parse_long_csv(Cursor::new(src), "x").unwrap();
        let e = &t.task("T").unwrap().executions[0];
        assert_eq!(e.samples, vec![2.0]);
        assert!((e.input_mb - 500.0).abs() < 1e-9);
    }

    #[test]
    fn rejects_bad_inputs() {
        assert!(parse_long_csv(Cursor::new("wrong header\n"), "x").is_err());
        assert!(parse_long_csv(Cursor::new(csv("T,notanum,1,2,3\n")), "x").is_err());
        assert!(parse_long_csv(Cursor::new(csv("T,1,1,2\n")), "x").is_err());
        assert!(parse_long_csv(Cursor::new(csv("T,1,1,2,3,4\n")), "x").is_err());
        assert!(parse_long_csv(Cursor::new(csv("T,1,-5,0,3\n")), "x").is_err());
        // identical timestamps
        assert!(parse_long_csv(Cursor::new(csv("T,1,1e9,5,1\nT,1,1e9,5,2\n")), "x").is_err());
    }

    #[test]
    fn imported_trace_feeds_predictor() {
        // End-to-end: long CSV -> Execution -> KS+ training.
        use crate::predictor::by_name;
        let mut body = String::new();
        for id in 0..12 {
            let input = 2e9 + id as f64 * 5e8;
            for t in 0..10 {
                let rss = if t < 7 { input * 0.4 } else { input * 0.9 };
                body.push_str(&format!("BWA,{id},{input},{},{rss}\n", t * 1000));
            }
        }
        let trace = parse_long_csv(Cursor::new(csv(&body)), "x").unwrap();
        let bwa = trace.task("BWA").unwrap();
        let mut p = by_name("ksplus", 2, 128.0).unwrap();
        p.train(&bwa.executions);
        let plan = p.plan(3000.0);
        assert!(plan.is_valid());
        assert!(plan.k() == 2, "{plan:?}");
    }
}
