//! Record/replay golden conformance for the coordinator.
//!
//! `record` drives a scripted client session against a live threaded
//! server with a [`DispatchTap`] installed at the service dispatch seam
//! and captures every request/response pair — plus raw-socket probes
//! for the decode-level errors that never reach dispatch — into a
//! versioned trace document (`ksplus-session-trace/v1`). `replay`
//! re-drives a trace against a fresh coordinator behind any front end
//! (threaded or event loop), any wire (v1 JSON lines or v2 binary), and
//! any shard count, and asserts the observable results are
//! bit-identical: every plan f64 is compared via `to_bits`, every error
//! by code and message.
//!
//! Two expectation modes make traces both machine-recordable and
//! hand-authorable:
//!
//! * a concrete `expect` document pins the response at record time and
//!   is checked on every replay;
//! * the sentinel `"cross-combo"` defers the expectation to replay
//!   time: the first replayed combo's result becomes the baseline the
//!   other combos must match bit-for-bit. This keeps committed goldens
//!   honest about computed f64s without requiring the author to know
//!   their exact bit patterns.
//!
//! Canonical forms deliberately exclude fields that are volatile
//! (latency percentiles, batch counts) or legitimately vary with the
//! replay topology (shard ids, the hello's shard count), so a trace
//! recorded at 2 shards replays cleanly at 3.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::coordinator::faults::FaultSpec;
use crate::coordinator::protocol::{ErrorCode, Request, Response, WireError};
use crate::coordinator::remote::RemoteClient;
use crate::coordinator::server::{Server, ServerConfig};
use crate::coordinator::service::{
    Client, Coordinator, CoordinatorConfig, DispatchTap, Dispatched,
};
use crate::coordinator::wire::{
    decode_response, read_frame, try_encode_request, FrameRead, Wire, DEFAULT_MAX_FRAME_BYTES,
};
use crate::coordinator::{BackendSpec, PredictorPolicy};
use crate::segments::StepPlan;
use crate::trace::Execution;
use crate::util::json::Json;

#[cfg(unix)]
use crate::coordinator::eventloop::EventLoopServer;

/// Schema tag every trace document carries.
pub const TRACE_SCHEMA: &str = "ksplus-session-trace/v1";
/// Expectation sentinel: the first replayed combo is the baseline.
pub const CROSS_COMBO: &str = "cross-combo";
/// File name of a committed golden inside `golden/<case>/`.
pub const TRACE_FILE: &str = "trace.json";

const TIMEOUT: Duration = Duration::from_secs(10);

// ---- trace documents -----------------------------------------------------

/// Coordinator + server shape a trace was recorded against and must be
/// replayed against (shard count may be overridden at replay time).
#[derive(Debug, Clone, PartialEq)]
pub struct CaseConfig {
    pub shards: usize,
    pub k: usize,
    pub max_conns: usize,
    pub max_frame_bytes: usize,
}

impl Default for CaseConfig {
    fn default() -> CaseConfig {
        CaseConfig {
            shards: 2,
            k: 3,
            max_conns: 32,
            max_frame_bytes: DEFAULT_MAX_FRAME_BYTES,
        }
    }
}

impl CaseConfig {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("shards", self.shards.into()),
            ("k", self.k.into()),
            ("max_conns", self.max_conns.into()),
            ("max_frame_bytes", self.max_frame_bytes.into()),
        ])
    }

    fn from_json(j: &Json) -> Result<CaseConfig> {
        let field = |key: &str| {
            j.get(key)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("trace config missing numeric '{key}'"))
        };
        Ok(CaseConfig {
            shards: field("shards")?,
            k: field("k")?,
            max_conns: field("max_conns")?,
            max_frame_bytes: field("max_frame_bytes")?,
        })
    }
}

/// What a recorded request is expected to produce on replay.
#[derive(Debug, Clone, PartialEq)]
pub enum Expect {
    /// Compare against the first replayed combo instead of a pinned
    /// document (see [`CROSS_COMBO`]).
    CrossCombo,
    /// A pinned v1 response document (`"ok":true` success or
    /// `"ok":false` error line), compared in canonical form.
    Json(Json),
}

/// One replayable step of a session.
#[derive(Debug, Clone, PartialEq)]
pub enum Step {
    /// A typed request driven through [`RemoteClient::call_raw`] on the
    /// session connection.
    Request { request: Json, expect: Expect },
    /// A named raw-socket probe (fresh connections) for behavior that
    /// typed requests cannot reach: decode-level errors, oversized
    /// frames, hello negotiation, connection limits.
    Probe { name: String },
}

/// A full recorded session: config, provenance, and ordered steps.
#[derive(Debug, Clone, PartialEq)]
pub struct SessionTrace {
    pub case_name: String,
    /// Informational provenance: how the trace was produced (front end,
    /// wire, negotiated version, or `"hand-authored"`).
    pub recorded: Json,
    pub config: CaseConfig,
    pub steps: Vec<Step>,
}

impl SessionTrace {
    pub fn to_json(&self) -> Json {
        let steps = self
            .steps
            .iter()
            .map(|s| match s {
                Step::Request { request, expect } => Json::obj(vec![
                    ("request", request.clone()),
                    (
                        "expect",
                        match expect {
                            Expect::CrossCombo => CROSS_COMBO.into(),
                            Expect::Json(j) => j.clone(),
                        },
                    ),
                ]),
                Step::Probe { name } => Json::obj(vec![("probe", name.as_str().into())]),
            })
            .collect();
        Json::obj(vec![
            ("schema", TRACE_SCHEMA.into()),
            ("case", self.case_name.as_str().into()),
            ("recorded", self.recorded.clone()),
            ("config", self.config.to_json()),
            ("steps", Json::Arr(steps)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<SessionTrace> {
        let schema = j.get("schema").and_then(Json::as_str).unwrap_or("");
        ensure!(
            schema == TRACE_SCHEMA,
            "unsupported trace schema '{schema}' (this build reads {TRACE_SCHEMA})"
        );
        let case_name = j
            .get("case")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("trace missing 'case'"))?
            .to_string();
        let config = CaseConfig::from_json(
            j.get("config").ok_or_else(|| anyhow!("trace missing 'config'"))?,
        )?;
        let raw_steps = j
            .get("steps")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("trace missing 'steps' array"))?;
        let mut steps = Vec::with_capacity(raw_steps.len());
        for (i, s) in raw_steps.iter().enumerate() {
            if let Some(name) = s.get("probe").and_then(Json::as_str) {
                ensure!(
                    probe_exists(name),
                    "step {i}: unknown probe '{name}' (known: {})",
                    probe_names().join(", ")
                );
                steps.push(Step::Probe { name: name.to_string() });
            } else if let Some(request) = s.get("request") {
                let expect = match s.get("expect") {
                    Some(Json::Str(s)) if s.as_str() == CROSS_COMBO => Expect::CrossCombo,
                    Some(doc) => Expect::Json(doc.clone()),
                    None => bail!("step {i}: request step missing 'expect'"),
                };
                steps.push(Step::Request { request: request.clone(), expect });
            } else {
                bail!("step {i}: neither a 'request' nor a 'probe' step");
            }
        }
        Ok(SessionTrace {
            case_name,
            recorded: j.get("recorded").cloned().unwrap_or(Json::Null),
            config,
            steps,
        })
    }

    pub fn write_file(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating {}", dir.display()))?;
        }
        let mut body = self.to_json().to_string();
        body.push('\n');
        std::fs::write(path, body).with_context(|| format!("writing {}", path.display()))
    }

    pub fn read_file(path: &Path) -> Result<SessionTrace> {
        let body = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let doc = Json::parse(&body)
            .map_err(|e| anyhow!("{} is not valid JSON: {e}", path.display()))?;
        SessionTrace::from_json(&doc)
            .with_context(|| format!("parsing trace {}", path.display()))
    }
}

// ---- canonical comparison forms ------------------------------------------

fn bits(xs: &[f64]) -> String {
    let hex: Vec<String> = xs.iter().map(|f| format!("{:016x}", f.to_bits())).collect();
    hex.join(",")
}

fn canonical_plan(p: &StepPlan) -> String {
    format!("starts={} peaks={}", bits(&p.starts), bits(&p.peaks))
}

fn canonical_error(e: &WireError) -> String {
    format!("err {}: {}", e.code.as_str(), e.message)
}

/// Snapshot docs list tasks in shard-iteration order, which varies with
/// topology; sort by task name before rendering. Rendering goes through
/// the shortest-roundtrip f64 formatter, so two different bit patterns
/// always render differently.
fn canonical_snapshot(doc: &Json) -> String {
    let mut doc = doc.clone();
    if let Json::Obj(map) = &mut doc {
        if let Some(Json::Arr(tasks)) = map.get_mut("tasks") {
            tasks.sort_by_key(|t| {
                t.get("task").and_then(Json::as_str).unwrap_or("").to_string()
            });
        }
    }
    doc.to_string()
}

/// The replay-stable projection of a response. Everything kept must be
/// bit-identical across front ends, wires, and shard counts; volatile
/// or topology-dependent fields (latencies, batch counts, shard ids)
/// are excluded.
pub fn canonical_response(resp: &Response) -> String {
    match resp {
        Response::Hello(i) => format!(
            "hello ops=[{}] policies=[{}]",
            i.ops.join(","),
            i.policies.join(",")
        ),
        Response::Configured { task, policy } => {
            format!("configured {} {}", task.as_deref().unwrap_or("*"), policy.name())
        }
        Response::Trained { task, executions } => {
            format!("trained {task} executions={executions}")
        }
        Response::Observed(a) => {
            format!(
                "observed {} executions={} predictor={}",
                a.task, a.executions, a.predictor
            )
        }
        Response::Planned(o) => format!(
            "planned predictor={} model_version={} fallback={} {}",
            o.predictor,
            o.model_version,
            o.fallback_reason.unwrap_or("-"),
            canonical_plan(&o.plan)
        ),
        Response::Retry(r) => {
            format!("retry predictor={} {}", r.predictor, canonical_plan(&r.plan))
        }
        Response::Stats(s) => format!(
            "stats requests={} failures_handled={} tasks_trained={} observations={} \
             fallbacks={} conns_refused={} conn_timeouts={} conns_overflowed={}",
            s.requests,
            s.failures_handled,
            s.tasks_trained,
            s.observations,
            s.fallbacks,
            s.conns_refused,
            s.conn_timeouts,
            s.conns_overflowed
        ),
        Response::Snapshot { doc } => format!("snapshot {}", canonical_snapshot(doc)),
        Response::Resharded { shard_ids } => format!("resharded n={}", shard_ids.len()),
    }
}

pub fn canonical_result(r: &Result<Response, WireError>) -> String {
    match r {
        Ok(resp) => canonical_response(resp),
        Err(e) => canonical_error(e),
    }
}

/// Canonical form of a pinned expect document (success or error line).
fn canonical_expect(op: &str, expect: &Json) -> Result<String> {
    match Response::from_json(expect, op) {
        Ok(resp) => Ok(canonical_response(&resp)),
        Err(e) if expect.get("ok").and_then(Json::as_bool) == Some(false) => {
            Ok(canonical_error(&e))
        }
        Err(e) => bail!("malformed expect for op '{op}': {} ({})", e.message, expect),
    }
}

// ---- the case registry ---------------------------------------------------

/// A scripted session action, turned into trace steps by `record`.
enum Action {
    Call(Request),
    Probe(&'static str),
}

/// Every golden case, in corpus order.
pub fn case_names() -> &'static [&'static str] {
    &["policies", "errors", "negotiation", "limits", "ops", "mixed-session"]
}

pub fn case_config(case: &str) -> Result<CaseConfig> {
    match case {
        "policies" | "errors" | "negotiation" | "ops" | "mixed-session" => {
            Ok(CaseConfig::default())
        }
        // Small caps so the oversize and connection-limit probes can
        // actually hit them.
        "limits" => Ok(CaseConfig {
            max_conns: 2,
            max_frame_bytes: 4096,
            ..CaseConfig::default()
        }),
        other => bail!("unknown case '{other}' (known: {})", case_names().join(", ")),
    }
}

/// Deterministic per-task history: the same bytes feed every combo.
fn history(task: &str, n: usize) -> Vec<Execution> {
    (0..n)
        .map(|i| {
            let input = 900.0 + 650.0 * i as f64;
            let len = 6 + i % 3;
            let samples: Vec<f64> = (0..len)
                .map(|j| 0.0005 * input * if j < len / 2 { 0.7 } else { 1.4 })
                .collect();
            Execution::new(task, input, 1.0, samples)
        })
        .collect()
}

fn one_exec(task: &str, input: f64) -> Execution {
    let samples: Vec<f64> = (0..8).map(|j| 0.0005 * input * (0.7 + 0.1 * j as f64)).collect();
    Execution::new(task, input, 1.0, samples)
}

fn call_train(task: &str, n: usize) -> Action {
    Action::Call(Request::Train {
        task: task.to_string(),
        history: history(task, n),
        dedup: None,
    })
}

fn call_plan(task: &str, input_mb: f64) -> Action {
    Action::Call(Request::Plan { task: task.to_string(), input_mb })
}

fn case_script(case: &str) -> Result<Vec<Action>> {
    let mut s: Vec<Action> = Vec::new();
    match case {
        // Every registered predictor policy: bind, train, plan, fold an
        // observation, plan again (the model-version bump must move the
        // plan deterministically).
        "policies" => {
            s.push(Action::Call(Request::Configure {
                task: None,
                policy: PredictorPolicy::KsPlus,
                dedup: None,
            }));
            for policy in [
                PredictorPolicy::KsPlus,
                PredictorPolicy::WittLr,
                PredictorPolicy::TovarPpm,
                PredictorPolicy::KSegments,
                PredictorPolicy::DefaultLimits,
            ] {
                let task = format!("po-{}", policy.name());
                s.push(Action::Call(Request::Configure {
                    task: Some(task.clone()),
                    policy,
                    dedup: None,
                }));
                s.push(call_train(&task, 12));
                for input in [1500.0, 4096.5, 9000.25] {
                    s.push(call_plan(&task, input));
                }
                s.push(Action::Call(Request::Observe {
                    task: task.clone(),
                    execution: one_exec(&task, 2200.0),
                    dedup: None,
                }));
                s.push(call_plan(&task, 4096.5));
            }
        }
        // Every parse-level structured error, plus the served fallback
        // path (an untrained task plans on default-limits).
        "errors" => {
            for probe in [
                "v1-garbage",
                "v2-garbage",
                "unknown-op",
                "missing-field",
                "invalid-field",
                "empty-history",
                "empty-samples",
                "invalid-plan",
                "unknown-policy",
            ] {
                s.push(Action::Probe(probe));
            }
            s.push(call_plan("never-trained", 512.0));
            s.push(Action::Call(Request::Stats));
        }
        // The hello negotiation matrix over live sockets.
        "negotiation" => {
            for probe in [
                "hello-default",
                "hello-v1-only",
                "hello-upgrade",
                "hello-bad-range",
                "hello-unsupported",
                "hello-max-zero",
            ] {
                s.push(Action::Probe(probe));
            }
        }
        // Resource-cap behavior: oversized requests and the connection
        // limit. Kept separate because the connection-limit probe's
        // retries make connection counters nondeterministic, so no
        // stats step may follow it.
        "limits" => {
            s.push(Action::Probe("oversized"));
            s.push(Action::Probe("conn-limit"));
        }
        // Admin ops: snapshot and reshard, with plans pinned across a
        // grow/shrink cycle.
        "ops" => {
            s.push(Action::Call(Request::Snapshot));
            s.push(Action::Call(Request::Configure {
                task: Some("op-task".to_string()),
                policy: PredictorPolicy::KsPlus,
                dedup: None,
            }));
            s.push(call_train("op-task", 10));
            s.push(call_plan("op-task", 3000.0));
            // Stats must precede the reshards: counters are per-shard
            // and merged over live shards, so a remove_shard may drop
            // counts — before any removal the merged sum is identical
            // at every shard count.
            s.push(Action::Call(Request::Stats));
            s.push(Action::Call(Request::Snapshot));
            s.push(Action::Call(Request::Reshard { shards: 3 }));
            s.push(call_plan("op-task", 3000.0));
            s.push(Action::Call(Request::Reshard { shards: 2 }));
            s.push(call_plan("op-task", 3000.0));
            s.push(Action::Call(Request::Snapshot));
        }
        // A multi-policy workload with a snapshot and a 2→3 reshard in
        // the middle: the replay split test cuts this one in half.
        "mixed-session" => {
            for (task, policy) in [
                ("mx-a", PredictorPolicy::KsPlus),
                ("mx-b", PredictorPolicy::WittLr),
                ("mx-c", PredictorPolicy::KSegments),
            ] {
                s.push(Action::Call(Request::Configure {
                    task: Some(task.to_string()),
                    policy,
                    dedup: None,
                }));
                s.push(call_train(task, 10));
                s.push(call_plan(task, 1800.0));
            }
            s.push(Action::Call(Request::Snapshot));
            s.push(Action::Call(Request::Reshard { shards: 3 }));
            for task in ["mx-a", "mx-b", "mx-c"] {
                s.push(Action::Call(Request::Observe {
                    task: task.to_string(),
                    execution: one_exec(task, 2600.0),
                    dedup: None,
                }));
                s.push(call_plan(task, 1800.0));
                s.push(call_plan(task, 7300.5));
            }
            s.push(Action::Call(Request::Snapshot));
        }
        other => bail!("unknown case '{other}'"),
    }
    Ok(s)
}

// ---- servers -------------------------------------------------------------

enum FrontHandle {
    Threaded(Server),
    #[cfg(unix)]
    Event(EventLoopServer),
}

/// A coordinator behind one of the two front ends, shaped by a case
/// config. Dropping it stops the server and the coordinator.
pub struct CaseServer {
    pub coord: Coordinator,
    front: FrontHandle,
}

impl CaseServer {
    pub fn addr(&self) -> SocketAddr {
        match &self.front {
            FrontHandle::Threaded(s) => s.addr(),
            #[cfg(unix)]
            FrontHandle::Event(s) => s.addr(),
        }
    }
}

/// Start a fresh coordinator + server for a case. `shards` overrides
/// the recorded shard count; `tap` is installed at the dispatch seam;
/// `fault_seed` arms the *benign* fault plane (short reads/writes and
/// dispatch stalls — nothing that alters response bytes), under which
/// every transcript must stay bit-identical to a fault-free run.
pub fn start_case_server(
    cfg: &CaseConfig,
    threaded: bool,
    shards: Option<usize>,
    tap: Option<Arc<dyn DispatchTap>>,
    fault_seed: Option<u64>,
) -> Result<CaseServer> {
    let coord = Coordinator::start(
        CoordinatorConfig {
            k: cfg.k,
            shards: shards.unwrap_or(cfg.shards),
            ..Default::default()
        },
        BackendSpec::Native,
    )
    .context("starting coordinator")?;
    let server_cfg = ServerConfig {
        max_conns: cfg.max_conns,
        max_frame_bytes: cfg.max_frame_bytes,
        tap,
        faults: fault_seed.map(|seed| FaultSpec::benign(seed).plane()),
        ..Default::default()
    };
    let front = if threaded {
        FrontHandle::Threaded(
            Server::start_with_config("127.0.0.1:0", coord.client(), server_cfg)
                .context("starting threaded server")?,
        )
    } else {
        start_event_front(coord.client(), server_cfg)?
    };
    Ok(CaseServer { coord, front })
}

#[cfg(unix)]
fn start_event_front(client: Client, cfg: ServerConfig) -> Result<FrontHandle> {
    Ok(FrontHandle::Event(
        EventLoopServer::start_with_config("127.0.0.1:0", client, cfg)
            .context("starting event-loop server")?,
    ))
}

#[cfg(not(unix))]
fn start_event_front(_client: Client, _cfg: ServerConfig) -> Result<FrontHandle> {
    bail!("the event-loop front end is unix-only")
}

/// The front-end × wire combinations a replay sweep covers. The first
/// entry is the cross-combo baseline.
pub fn all_combos() -> Vec<(&'static str, bool, Wire)> {
    let mut v = vec![("threaded-v1", true, Wire::V1), ("threaded-v2", true, Wire::V2)];
    #[cfg(unix)]
    {
        v.push(("eventloop-v1", false, Wire::V1));
        v.push(("eventloop-v2", false, Wire::V2));
    }
    v
}

// ---- record --------------------------------------------------------------

/// Tap that logs `(request, outcome)` pairs while armed. Recording uses
/// a single sequential client, so arming around each scripted call
/// keeps negotiation hellos and probe traffic out of the log.
struct RecordingTap {
    armed: AtomicBool,
    log: Mutex<Vec<(Json, Json)>>,
}

impl DispatchTap for RecordingTap {
    fn observe(&self, req: &Request, out: &Dispatched) {
        if !self.armed.load(Ordering::SeqCst) {
            return;
        }
        let outcome = match out {
            Dispatched::Reply(resp) => resp.to_json(),
            Dispatched::Hello(resp, _) => resp.to_json(),
            Dispatched::Error(e) => e.to_json(),
        };
        self.log.lock().unwrap().push((req.to_json(), outcome));
    }
}

/// Drive a case script against a tapped threaded server and capture it
/// as a trace. Expectations come from the server side of the dispatch
/// seam and are cross-checked against what the client observed on the
/// wire — recording fails loudly if the two ever disagree.
pub fn record_case(case: &str) -> Result<SessionTrace> {
    let cfg = case_config(case)?;
    let script = case_script(case)?;
    let tap = Arc::new(RecordingTap { armed: AtomicBool::new(false), log: Mutex::new(Vec::new()) });
    let server = start_case_server(
        &cfg,
        true,
        None,
        Some(Arc::clone(&tap) as Arc<dyn DispatchTap>),
        None,
    )?;
    let addr = server.addr();
    let mut rc = RemoteClient::connect_with_timeout(addr, TIMEOUT)?;
    rc.set_read_timeout(Some(TIMEOUT))?;
    let info = rc.negotiate(Wire::V1.version())?;

    let mut steps = Vec::with_capacity(script.len());
    for (i, action) in script.into_iter().enumerate() {
        match action {
            Action::Call(req) => {
                tap.armed.store(true, Ordering::SeqCst);
                let client_side = rc.call_raw(&req)?;
                tap.armed.store(false, Ordering::SeqCst);
                let mut captured = std::mem::take(&mut *tap.log.lock().unwrap());
                ensure!(
                    captured.len() == 1,
                    "step {i} ({}): tap captured {} dispatches, expected 1",
                    req.op(),
                    captured.len()
                );
                let (tap_req, tap_out) = captured.remove(0);
                ensure!(
                    tap_req.to_string() == req.to_json().to_string(),
                    "step {i}: tap saw a different request: {tap_req} vs {}",
                    req.to_json()
                );
                let server_canon = canonical_expect(req.op(), &tap_out)?;
                let client_canon = canonical_result(&client_side);
                ensure!(
                    server_canon == client_canon,
                    "step {i} ({}): dispatch seam and wire disagree:\n  seam: {server_canon}\n  wire: {client_canon}",
                    req.op()
                );
                steps.push(Step::Request {
                    request: req.to_json(),
                    expect: Expect::Json(tap_out),
                });
            }
            Action::Probe(name) => {
                // Probes self-check; at record time we only prove they
                // pass so the trace is replayable as written.
                run_probe(addr, name, &cfg)
                    .with_context(|| format!("step {i}: probe '{name}' failed at record time"))?;
                steps.push(Step::Probe { name: name.to_string() });
            }
        }
    }
    Ok(SessionTrace {
        case_name: case.to_string(),
        recorded: Json::obj(vec![
            ("server", "threaded".into()),
            ("wire", Wire::V1.name().into()),
            ("negotiated_version", info.version.into()),
        ]),
        config: cfg,
        steps,
    })
}

// ---- replay --------------------------------------------------------------

/// Drive a slice of steps over an existing session connection, checking
/// pinned expects, and return the canonical transcript (one line per
/// observable result). Exposed at step granularity so tests can split a
/// trace across a snapshot/restore or reshard boundary.
pub fn replay_steps(
    addr: SocketAddr,
    rc: &mut RemoteClient,
    cfg: &CaseConfig,
    steps: &[Step],
) -> Result<Vec<String>> {
    let mut transcript = Vec::new();
    for (i, step) in steps.iter().enumerate() {
        match step {
            Step::Request { request, expect } => {
                let line = request.to_string();
                let req = Request::parse(&line).map_err(|e| {
                    anyhow!("step {i}: trace request does not parse: {} ({line})", e.message)
                })?;
                let got = rc
                    .call_raw(&req)
                    .with_context(|| format!("step {i} ({}) transport failure", req.op()))?;
                let got_canon = canonical_result(&got);
                if let Expect::Json(doc) = expect {
                    let want_canon = canonical_expect(req.op(), doc)
                        .with_context(|| format!("step {i}"))?;
                    ensure!(
                        got_canon == want_canon,
                        "step {i} ({}) diverged from the pinned expect:\n  want: {want_canon}\n  got:  {got_canon}",
                        req.op()
                    );
                }
                transcript.push(format!("{} {}", req.op(), got_canon));
            }
            Step::Probe { name } => {
                let mut lines = run_probe(addr, name, cfg)
                    .with_context(|| format!("step {i}: probe '{name}'"))?;
                transcript.append(&mut lines);
            }
        }
    }
    Ok(transcript)
}

/// Replay a whole trace against a fresh server and return the canonical
/// transcript. Cross-combo comparison is the caller's job: transcripts
/// from different combos of the same trace must be identical.
pub fn replay_trace(
    trace: &SessionTrace,
    threaded: bool,
    wire: Wire,
    shards: Option<usize>,
) -> Result<Vec<String>> {
    replay_trace_faulted(trace, threaded, wire, shards, None)
}

/// [`replay_trace`] with the benign fault plane armed from a seed: the
/// server's reads, writes, and dispatch scheduling are perturbed
/// deterministically while the transcript must not move a bit. A
/// divergence under `--fault-seed` is a partial-frame reassembly or
/// ordering bug, not a model bug.
pub fn replay_trace_faulted(
    trace: &SessionTrace,
    threaded: bool,
    wire: Wire,
    shards: Option<usize>,
    fault_seed: Option<u64>,
) -> Result<Vec<String>> {
    let server = start_case_server(&trace.config, threaded, shards, None, fault_seed)?;
    let mut rc = RemoteClient::connect_with_timeout(server.addr(), TIMEOUT)?;
    rc.set_read_timeout(Some(TIMEOUT))?;
    let info = rc.negotiate(wire.version()).context("negotiating the session wire")?;
    ensure!(
        info.version == wire.version(),
        "negotiation granted v{} but the combo wants {}",
        info.version,
        wire.name()
    );
    replay_steps(server.addr(), &mut rc, &trace.config, &trace.steps)
}

// ---- probes --------------------------------------------------------------

fn probe_names() -> Vec<&'static str> {
    vec![
        "v1-garbage",
        "v2-garbage",
        "unknown-op",
        "missing-field",
        "invalid-field",
        "empty-history",
        "empty-samples",
        "invalid-plan",
        "unknown-policy",
        "oversized",
        "conn-limit",
        "hello-default",
        "hello-v1-only",
        "hello-upgrade",
        "hello-bad-range",
        "hello-unsupported",
        "hello-max-zero",
    ]
}

fn probe_exists(name: &str) -> bool {
    probe_names().contains(&name)
}

fn probe_conn(addr: SocketAddr) -> Result<(TcpStream, BufReader<TcpStream>)> {
    let stream = TcpStream::connect(addr).context("probe connect")?;
    stream.set_read_timeout(Some(TIMEOUT))?;
    let reader = BufReader::new(stream.try_clone()?);
    Ok((stream, reader))
}

fn v1_line(
    stream: &mut TcpStream,
    reader: &mut BufReader<TcpStream>,
    line: &str,
) -> Result<Json> {
    writeln!(stream, "{line}")?;
    let mut resp = String::new();
    reader.read_line(&mut resp)?;
    ensure!(!resp.is_empty(), "connection closed instead of replying to {line}");
    Json::parse(&resp).map_err(|e| anyhow!("unparseable response line: {e}"))
}

fn error_of(j: &Json) -> Result<WireError> {
    ensure!(
        j.get("ok").and_then(Json::as_bool) == Some(false),
        "expected an error line, got {j}"
    );
    Ok(WireError::from_json(j))
}

fn expect_code(name: &str, got: &WireError, want: ErrorCode) -> Result<()> {
    ensure!(
        got.code == want,
        "probe {name}: expected {}, got {}: {}",
        want.as_str(),
        got.code.as_str(),
        got.message
    );
    Ok(())
}

fn at_eof(reader: &mut BufReader<TcpStream>) -> bool {
    let mut one = [0u8; 1];
    matches!(reader.read(&mut one), Ok(0))
}

/// Upgrade a fresh connection to the v2 binary wire via a v1 hello.
fn upgrade_to_v2(
    stream: &mut TcpStream,
    reader: &mut BufReader<TcpStream>,
) -> Result<()> {
    let j = v1_line(stream, reader, r#"{"op":"hello","max_version":2}"#)?;
    ensure!(
        j.get("version").and_then(Json::as_usize) == Some(2),
        "v2 upgrade not granted: {j}"
    );
    Ok(())
}

/// Run one named probe against the server on fresh connections and
/// return its canonical transcript lines. Probes carry their expected
/// error codes in code — the trace only names them — so a golden stays
/// hand-authorable while the assertions stay exact.
pub fn run_probe(addr: SocketAddr, name: &str, cfg: &CaseConfig) -> Result<Vec<String>> {
    // Parse-level error probes: one bad v1 line, one structured error,
    // connection stays open (proved with a stats roundtrip).
    let v1_error_table: &[(&str, &str, ErrorCode)] = &[
        ("v1-garbage", "### not json", ErrorCode::InvalidJson),
        ("unknown-op", r#"{"op":"frobnicate"}"#, ErrorCode::UnknownOp),
        ("missing-field", r#"{"op":"plan"}"#, ErrorCode::MissingField),
        (
            "invalid-field",
            r#"{"op":"plan","task":"t","input_mb":"much"}"#,
            ErrorCode::InvalidField,
        ),
        (
            "empty-history",
            r#"{"op":"train","task":"t","history":[]}"#,
            ErrorCode::EmptyHistory,
        ),
        (
            "empty-samples",
            r#"{"op":"observe","task":"t","execution":{"input_mb":10,"dt":1.0,"samples":[]}}"#,
            ErrorCode::EmptySamples,
        ),
        (
            "invalid-plan",
            r#"{"op":"failure","plan":{"starts":[0.0,5.0],"peaks":[2.0]},"fail_time":1.0}"#,
            ErrorCode::InvalidPlan,
        ),
        (
            "unknown-policy",
            r#"{"op":"configure","task":"t","policy":"nope"}"#,
            ErrorCode::UnknownPolicy,
        ),
    ];
    if let Some((_, line, want)) = v1_error_table.iter().find(|(n, _, _)| *n == name) {
        let (mut stream, mut reader) = probe_conn(addr)?;
        let err = error_of(&v1_line(&mut stream, &mut reader, line)?)?;
        expect_code(name, &err, *want)?;
        let after = v1_line(&mut stream, &mut reader, r#"{"op":"stats"}"#)?;
        ensure!(
            after.get("ok").and_then(Json::as_bool) == Some(true),
            "probe {name}: connection wedged after the error"
        );
        return Ok(vec![format!("probe {name}: {} still-open=ok", canonical_error(&err))]);
    }

    match name {
        // An unknown tag on the binary wire draws invalid-frame.
        "v2-garbage" => {
            let (mut stream, mut reader) = probe_conn(addr)?;
            upgrade_to_v2(&mut stream, &mut reader)?;
            let mut frame = (5u32).to_le_bytes().to_vec();
            frame.extend_from_slice(&[0x7E, 1, 2, 3, 4]);
            stream.write_all(&frame)?;
            let err = match read_frame(&mut reader, Wire::V2, DEFAULT_MAX_FRAME_BYTES)? {
                FrameRead::Frame(payload) => decode_response(Wire::V2, &payload, "probe")
                    .err()
                    .ok_or_else(|| anyhow!("probe {name}: got a success response"))?,
                other => bail!("probe {name}: expected an error frame, got {other:?}"),
            };
            expect_code(name, &err, ErrorCode::InvalidFrame)?;
            Ok(vec![format!("probe {name}: {}", canonical_error(&err))])
        }
        // Over-cap requests draw request-too-large and a close, on both
        // wires; the v2 refusal happens on the length header alone.
        "oversized" => {
            let mut out = Vec::new();
            let (mut stream, mut reader) = probe_conn(addr)?;
            let long = "x".repeat(cfg.max_frame_bytes + 1);
            let err = error_of(&v1_line(&mut stream, &mut reader, &long)?)?;
            expect_code(name, &err, ErrorCode::RequestTooLarge)?;
            ensure!(at_eof(&mut reader), "probe {name}: v1 connection stayed open");
            out.push(format!("probe {name}: v1 {} closed=ok", canonical_error(&err)));

            let (mut stream, mut reader) = probe_conn(addr)?;
            upgrade_to_v2(&mut stream, &mut reader)?;
            stream.write_all(&((cfg.max_frame_bytes as u32) + 1).to_le_bytes())?;
            let err = match read_frame(&mut reader, Wire::V2, DEFAULT_MAX_FRAME_BYTES)? {
                FrameRead::Frame(payload) => decode_response(Wire::V2, &payload, "probe")
                    .err()
                    .ok_or_else(|| anyhow!("probe {name}: got a success response"))?,
                other => bail!("probe {name}: expected an error frame, got {other:?}"),
            };
            expect_code(name, &err, ErrorCode::RequestTooLarge)?;
            ensure!(at_eof(&mut reader), "probe {name}: v2 connection stayed open");
            out.push(format!("probe {name}: v2 {} closed=ok", canonical_error(&err)));
            Ok(out)
        }
        // Fill the connection table; at least one admission must be
        // refused with the structured error (the session connection
        // already holds a slot). Afterwards, prove the server admits
        // again once the probe connections are gone.
        "conn-limit" => {
            let mut refusal: Option<WireError> = None;
            let mut held = Vec::new();
            for _ in 0..cfg.max_conns {
                let stream = TcpStream::connect(addr)?;
                stream.set_read_timeout(Some(Duration::from_millis(300)))?;
                let mut reader = BufReader::new(stream.try_clone()?);
                let mut line = String::new();
                match reader.read_line(&mut line) {
                    Ok(n) if n > 0 => {
                        let j = Json::parse(&line)
                            .map_err(|e| anyhow!("unparseable refusal: {e}"))?;
                        let err = error_of(&j)?;
                        expect_code(name, &err, ErrorCode::TooManyConnections)?;
                        refusal.get_or_insert(err);
                    }
                    _ => held.push(stream), // admitted: nothing to read
                }
            }
            let refusal = refusal.ok_or_else(|| {
                anyhow!("probe {name}: no refusal within {} connections", cfg.max_conns)
            })?;
            drop(held);
            // Server-side slot release is asynchronous; poll until a
            // fresh connection serves a request again.
            let mut recovered = false;
            for _ in 0..100 {
                if let Ok((mut s, mut r)) = probe_conn(addr) {
                    if let Ok(j) = v1_line(&mut s, &mut r, r#"{"op":"hello"}"#) {
                        if j.get("ok").and_then(Json::as_bool) == Some(true) {
                            recovered = true;
                            break;
                        }
                    }
                }
                std::thread::sleep(Duration::from_millis(20));
            }
            ensure!(recovered, "probe {name}: server never admitted connections again");
            Ok(vec![format!(
                "probe {name}: {} recovered=ok",
                canonical_error(&refusal)
            )])
        }
        // The negotiation matrix. Grants also canonicalize the hello
        // body; the upgrade probe proves the codec switch by speaking
        // v2 immediately after.
        "hello-default" | "hello-v1-only" | "hello-upgrade" => {
            let (line, want_version) = match name {
                "hello-default" => (r#"{"op":"hello"}"#, 1),
                "hello-v1-only" => (r#"{"op":"hello","min_version":1,"max_version":1}"#, 1),
                _ => (r#"{"op":"hello","max_version":2}"#, 2),
            };
            let (mut stream, mut reader) = probe_conn(addr)?;
            let j = v1_line(&mut stream, &mut reader, line)?;
            let resp = Response::from_json(&j, "hello")
                .map_err(|e| anyhow!("probe {name}: hello failed: {}", e.message))?;
            let version = j.get("version").and_then(Json::as_usize);
            ensure!(
                version == Some(want_version),
                "probe {name}: granted {version:?}, wanted v{want_version}"
            );
            let mut out = format!(
                "probe {name}: version={want_version} {}",
                canonical_response(&resp)
            );
            if want_version == 2 {
                let bytes =
                    try_encode_request(Wire::V2, &Request::Stats, DEFAULT_MAX_FRAME_BYTES)
                        .map_err(|e| anyhow!("encoding the switch proof: {}", e.message))?;
                stream.write_all(&bytes)?;
                match read_frame(&mut reader, Wire::V2, DEFAULT_MAX_FRAME_BYTES)? {
                    FrameRead::Frame(payload) => {
                        decode_response(Wire::V2, &payload, "stats")
                            .map_err(|e| anyhow!("probe {name}: post-upgrade stats failed: {}", e.message))?;
                    }
                    other => bail!("probe {name}: expected a v2 frame, got {other:?}"),
                }
                out.push_str(" switched=ok");
            }
            Ok(vec![out])
        }
        "hello-bad-range" | "hello-unsupported" | "hello-max-zero" => {
            let (line, want) = match name {
                "hello-bad-range" => (
                    r#"{"op":"hello","min_version":3,"max_version":1}"#,
                    ErrorCode::InvalidField,
                ),
                "hello-unsupported" => {
                    (r#"{"op":"hello","min_version":99}"#, ErrorCode::UnsupportedVersion)
                }
                _ => (r#"{"op":"hello","max_version":0}"#, ErrorCode::UnsupportedVersion),
            };
            let (mut stream, mut reader) = probe_conn(addr)?;
            let err = error_of(&v1_line(&mut stream, &mut reader, line)?)?;
            expect_code(name, &err, want)?;
            // A failed negotiation must leave the connection serviceable
            // on v1.
            let after = v1_line(&mut stream, &mut reader, r#"{"op":"stats"}"#)?;
            ensure!(
                after.get("ok").and_then(Json::as_bool) == Some(true),
                "probe {name}: connection wedged after the failed hello"
            );
            Ok(vec![format!(
                "probe {name}: {} still-open=ok",
                canonical_error(&err)
            )])
        }
        other => bail!("unknown probe '{other}'"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_documents_roundtrip_through_json() {
        let trace = SessionTrace {
            case_name: "policies".to_string(),
            recorded: Json::obj(vec![("server", "hand-authored".into())]),
            config: CaseConfig { shards: 2, k: 3, max_conns: 8, max_frame_bytes: 4096 },
            steps: vec![
                Step::Request {
                    request: Request::Stats.to_json(),
                    expect: Expect::CrossCombo,
                },
                Step::Request {
                    request: Request::Reshard { shards: 3 }.to_json(),
                    expect: Expect::Json(
                        Response::Resharded { shard_ids: vec![0, 1, 2] }.to_json(),
                    ),
                },
                Step::Probe { name: "v1-garbage".to_string() },
            ],
        };
        let doc = trace.to_json();
        let back = SessionTrace::from_json(&doc).unwrap();
        assert_eq!(trace, back);
        // And through actual text, the way a committed golden lives.
        let reparsed = Json::parse(&doc.to_string()).unwrap();
        assert_eq!(SessionTrace::from_json(&reparsed).unwrap(), trace);
    }

    #[test]
    fn from_json_rejects_bad_documents() {
        let bad_schema = Json::obj(vec![("schema", "nope/v9".into())]);
        assert!(SessionTrace::from_json(&bad_schema).is_err());

        let trace = SessionTrace {
            case_name: "x".to_string(),
            recorded: Json::Null,
            config: CaseConfig::default(),
            steps: vec![Step::Probe { name: "no-such-probe".to_string() }],
        };
        let err = SessionTrace::from_json(&trace.to_json()).unwrap_err();
        assert!(err.to_string().contains("unknown probe"), "{err}");
    }

    #[test]
    fn canonical_forms_exclude_volatile_fields() {
        use crate::coordinator::protocol::StatsSummary;
        let mut s = StatsSummary { requests: 7, latency_p50_us: 12.5, ..Default::default() };
        let a = canonical_response(&Response::Stats(s.clone()));
        s.latency_p50_us = 99.0;
        s.batches = 42;
        s.shards = 5;
        let b = canonical_response(&Response::Stats(s));
        assert_eq!(a, b, "latency/batches/shards must not affect the canonical form");

        let c = canonical_response(&Response::Resharded { shard_ids: vec![0, 1, 2] });
        let d = canonical_response(&Response::Resharded { shard_ids: vec![4, 7, 9] });
        assert_eq!(c, d, "shard ids are topology, only the count is conformance");
    }

    #[test]
    fn canonical_plans_compare_bits_not_formatting() {
        let a = StepPlan::new(vec![0.0, 2.0], vec![1.0, 3.0]);
        let mut b = a.clone();
        // A 1-ulp nudge must change the canonical form even though many
        // formatters would round it away.
        b.peaks[1] = f64::from_bits(b.peaks[1].to_bits() + 1);
        assert_ne!(canonical_plan(&a), canonical_plan(&b));
    }

    #[test]
    fn every_case_has_a_config_and_script() {
        for case in case_names() {
            case_config(case).unwrap();
            let script = case_script(case).unwrap();
            assert!(!script.is_empty(), "case {case} has an empty script");
        }
        assert!(case_config("bogus").is_err());
    }

    #[test]
    fn expect_documents_canonicalize_both_ways() {
        let ok = Response::Trained { task: "t".to_string(), executions: 12 }.to_json();
        assert_eq!(canonical_expect("train", &ok).unwrap(), "trained t executions=12");
        let err = WireError::new(ErrorCode::UnknownPolicy, "unknown policy 'nope'").to_json();
        assert_eq!(
            canonical_expect("configure", &err).unwrap(),
            "err unknown-policy: unknown policy 'nope'"
        );
    }
}
