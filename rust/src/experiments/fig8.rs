//! Fig 8: per-task memory wastage for the nine predicted eager tasks,
//! per method and training fraction.
//!
//! Paper shape: bwa dominates total wastage; KS+ cuts it by ~40 % vs the
//! best baseline; mtnucratio shows the largest relative reduction; a
//! couple of small tasks may slightly regress vs k-Segments Selective.

use anyhow::Result;

use crate::experiments::{eval_traces, evaluate_method, report, ExpConfig, ExpOutput};
use crate::predictor::paper_methods;
use crate::util::json::Json;
use crate::util::stats;

/// (task, method, frac) -> per-seed wastage.
pub type TaskCells = Vec<(String, &'static str, f64, Vec<f64>)>;

pub fn collect(cfg: &ExpConfig) -> Result<TaskCells> {
    // First evaluation source: eager (the paper's Fig 8 workflow), or
    // the ingested CSV under --trace.
    let mut sources = eval_traces(cfg)?;
    let (wf, trace, _label) = sources.swap_remove(0);
    let tasks: Vec<String> = trace.tasks.iter().map(|t| t.task.clone()).collect();
    let mut cells: TaskCells = Vec::new();
    for &frac in &cfg.train_fracs {
        for method in paper_methods() {
            // One evaluation per seed yields every task's wastage at once.
            let mut per_task: std::collections::BTreeMap<String, Vec<f64>> =
                tasks.iter().map(|t| (t.clone(), Vec::new())).collect();
            for &seed in &cfg.seeds {
                let r = evaluate_method(method, cfg.k, cfg.capacity_gb, &wf, &trace, frac, seed)?;
                for t in &tasks {
                    per_task.get_mut(t).unwrap().push(r.task_wastage(t));
                }
            }
            for t in &tasks {
                cells.push((t.clone(), method, frac, per_task[t].clone()));
            }
        }
    }
    Ok(cells)
}

pub fn run(cfg: &ExpConfig) -> Result<ExpOutput> {
    let cells = collect(cfg)?;
    let mut text = String::new();
    let mut json_rows = Vec::new();
    let label = if cfg.trace_csv.is_some() { "trace" } else { "eager" };
    // Task rows in trace order (counts order for the synthetic source).
    let mut task_names: Vec<String> = Vec::new();
    for (t, ..) in &cells {
        if !task_names.contains(t) {
            task_names.push(t.clone());
        }
    }

    for &frac in &cfg.train_fracs {
        let mut table = report::Table::new(
            &["task", "ksplus", "kseg-sel", "kseg-par", "tovar", "ppm-impr", "default"],
        );
        for task in &task_names {
            let mut row = vec![task.to_string()];
            for method in paper_methods() {
                let cell = cells
                    .iter()
                    .find(|(t, m, f, _)| t == task && *m == method && *f == frac)
                    .unwrap();
                row.push(report::f(stats::mean(&cell.3)));
                json_rows.push(Json::obj(vec![
                    ("task", task.as_str().into()),
                    ("method", method.into()),
                    ("train_frac", frac.into()),
                    ("wastage_gbs_mean", stats::mean(&cell.3).into()),
                ]));
            }
            table.row(row);
        }
        text.push_str(&table.render(&format!(
            "Fig 8 ({label}, {:.0}% train): per-task wastage GBs",
            frac * 100.0
        )));
        text.push('\n');
    }
    Ok(ExpOutput { text, json: Json::obj(vec![("fig8", Json::Arr(json_rows))]) })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ExpConfig {
        ExpConfig { seeds: vec![1], train_fracs: vec![0.5], ..Default::default() }
    }

    #[test]
    fn covers_all_tasks_and_methods() {
        let cells = collect(&tiny_cfg()).unwrap();
        assert_eq!(cells.len(), 9 * 6);
    }

    #[test]
    fn bwa_dominates_wastage() {
        let cells = collect(&tiny_cfg()).unwrap();
        // For the default method, bwa should be the largest contributor
        // (as in the paper).
        let default_cells: Vec<_> =
            cells.iter().filter(|(_, m, _, _)| *m == "default").collect();
        let bwa = default_cells.iter().find(|(t, ..)| t == "bwa").unwrap().3[0];
        for (t, _, _, w) in &default_cells {
            if t != "bwa" {
                assert!(bwa >= w[0], "bwa {bwa} < {t} {}", w[0]);
            }
        }
    }

    #[test]
    fn report_renders_tables() {
        let out = run(&tiny_cfg()).unwrap();
        assert!(out.text.contains("Fig 8 (eager"));
        assert!(out.text.contains("bwa"));
    }

    #[test]
    fn trace_csv_drives_fig8() {
        let cfg = ExpConfig {
            trace_csv: Some(
                concat!(
                    env!("CARGO_MANIFEST_DIR"),
                    "/../golden/traces/nfcore_rnaseq_sample.csv"
                )
                .into(),
            ),
            ..tiny_cfg()
        };
        let cells = collect(&cfg).unwrap();
        // 3 CSV tasks x 6 methods x 1 frac.
        assert_eq!(cells.len(), 3 * 6);
        let out = run(&cfg).unwrap();
        assert!(out.text.contains("Fig 8 (trace"), "{}", out.text);
        assert!(out.text.contains("STAR_ALIGN"));
    }
}
