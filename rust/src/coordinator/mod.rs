//! Online memory-prediction service: the deployment surface a workflow
//! engine (Nextflow/Airflow/Snakemake) would call before submitting each
//! task to the resource manager.
//!
//! Architecture (std threads + channels; see DESIGN.md Section 5b). The
//! coordinator is a pool of `shards` identical workers; every worker
//! owns its own model store, numeric backend, and dynamic batcher:
//!
//! ```text
//!                ┌─hash(task)──▶ worker 0 (store + backend + batcher)
//!   clients ──┬──┤              worker 1 (store + backend + batcher)
//!             │  └─hash(task)──▶ ...
//!             │                 worker N-1 (store + backend + batcher)
//!             │   each worker:
//!             │     ├─ Train    : fold of Observe over the history
//!             │     ├─ Observe  : O(k) incremental update — segment ONE
//!             │     │             new execution, fold it into the 2k
//!             │     │             OLS sufficient-stat accumulators,
//!             │     │             refit the closed forms
//!             │     ├─ Plan     : dynamic batcher — collects up to
//!             │     │             `batch_max` requests or `batch_delay`,
//!             │     │             then ONE batched predict over the
//!             │     │             queued task×segment models
//!             │     └─ Failure  : KS+ segment-rescaling retry
//!             │                   (stateless; round-robin over shards)
//!             └──fan-out───────▶ Stats : merged across every shard
//! ```
//!
//! `Train`, `Observe`, and `Plan` route by a deterministic FNV-1a hash of
//! the task name (`service::shard_for`), so one shard owns each task's
//! models and its plan traffic; `shards: 1` (the default) reproduces the
//! original single-worker coordinator. Training is *incremental*: the
//! store keeps per-task sufficient statistics (n, Σx, Σy, Σx², Σxy) for
//! every one of the 2k regressions, so observing a finished execution
//! costs one segmentation of that execution plus O(k) accumulator
//! updates — history is never re-segmented — and a batch `Train` is
//! literally a fold of `Observe`, making the two bit-identical. Each
//! per-shard batcher is the L3 hot path: with the `pjrt` cargo feature
//! every flush is a single PJRT execution of `predict_b{B}.hlo.txt`
//! covering every queued request's 2k regression evaluations; in default
//! (native-only) builds the same flush runs the closed-form OLS
//! in-process. The Python stack is never invoked either way.

pub mod server;
pub mod service;

use crate::predictor::ksplus::{KsPlus, MEM_OVERPREDICT, TIME_UNDERPREDICT};
use crate::predictor::regression::{LinModel, OlsStats};
#[cfg(feature = "pjrt")]
use crate::runtime::Runtime;
use crate::segments::StepPlan;
use crate::trace::Execution;

/// Numeric backend for the coordinator. PJRT handles are thread-affine
/// (`Rc`): the service constructs its backend *inside* the worker thread
/// from a `BackendSpec`. The PJRT variant only exists when the crate is
/// compiled with the `pjrt` feature; `Backend::Native` is always there.
#[derive(Clone)]
pub enum Backend {
    /// In-process closed form (tests, environments without artifacts).
    Native,
    /// AOT Pallas kernels through PJRT (production path, `pjrt` feature).
    #[cfg(feature = "pjrt")]
    Pjrt(std::rc::Rc<Runtime>),
}

/// Send-able description of a backend, resolved on the worker thread.
///
/// `BackendSpec::Pjrt` is always available to *describe* — callers such
/// as the CLI and the wire protocol compile unchanged either way — but
/// `build()` returns a runtime error in a native-only build.
#[derive(Debug, Clone)]
pub enum BackendSpec {
    Native,
    /// Load artifacts from this directory (or the default location).
    Pjrt(Option<std::path::PathBuf>),
}

impl BackendSpec {
    /// Whether this spec can be built in this binary (the native backend
    /// always can; PJRT needs the `pjrt` cargo feature).
    pub fn available(&self) -> bool {
        match self {
            BackendSpec::Native => true,
            BackendSpec::Pjrt(_) => cfg!(feature = "pjrt"),
        }
    }

    pub fn build(&self) -> anyhow::Result<Backend> {
        match self {
            BackendSpec::Native => Ok(Backend::Native),
            #[cfg(feature = "pjrt")]
            BackendSpec::Pjrt(dir) => {
                let dir = dir
                    .clone()
                    .unwrap_or_else(crate::runtime::default_artifacts_dir);
                Ok(Backend::Pjrt(std::rc::Rc::new(Runtime::load(&dir)?)))
            }
            #[cfg(not(feature = "pjrt"))]
            BackendSpec::Pjrt(_) => anyhow::bail!(
                "the PJRT backend was not compiled into this binary; rebuild \
                 with `cargo build --features pjrt`, or use BackendSpec::Native"
            ),
        }
    }
}

impl Backend {
    pub fn name(&self) -> &'static str {
        match self {
            Backend::Native => "native",
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(_) => "pjrt",
        }
    }

    /// Evaluate `models[i]` at `xq[i]`, scaled by `scale[i]` and clamped
    /// at zero, into `out` (cleared first). The reusable `out` buffer is
    /// what lets a steady-state batcher flush avoid fresh allocations.
    fn predict_into(&self, models: &[LinModel], xq: &[f64], scale: &[f64], out: &mut Vec<f64>) {
        out.clear();
        match self {
            Backend::Native => out.extend(
                models
                    .iter()
                    .zip(xq.iter().zip(scale))
                    .map(|(m, (x, s))| (m.predict(*x) * s).max(0.0)),
            ),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(rt) => {
                out.extend(rt.predict_batch(models, xq, scale).expect("PJRT predict"))
            }
        }
    }
}

/// Per-task model state: the 2k sufficient-statistic accumulators
/// (k segment starts, then k segment peaks) plus the closed-form models
/// refit from them after every observation.
#[derive(Debug, Clone)]
pub struct TaskModels {
    /// Sufficient statistics for the 2k regressions.
    stats: Vec<OlsStats>,
    pub start_models: Vec<LinModel>,
    pub peak_models: Vec<LinModel>,
    /// Highest peak seen so far. Exposed for introspection (mirrors the
    /// KsPlus batch rule max(peaks…, 0.1)); the store's unknown-task
    /// fallback can never consult it, because an unknown task has no
    /// `TaskModels` entry at all.
    pub fallback_peak: f64,
    /// Executions folded in so far.
    pub observed: u64,
}

impl TaskModels {
    fn empty(k: usize) -> TaskModels {
        TaskModels {
            stats: vec![OlsStats::default(); 2 * k],
            start_models: Vec::new(),
            peak_models: Vec::new(),
            // Matches the batch rule max(peaks… , 0.1) once peaks fold in.
            fallback_peak: 0.1,
            observed: 0,
        }
    }

    /// Refit the 2k closed forms from the accumulators. O(k).
    fn refit(&mut self, k: usize) {
        self.start_models.clear();
        self.start_models.extend(self.stats[..k].iter().map(OlsStats::fit));
        self.peak_models.clear();
        self.peak_models.extend(self.stats[k..].iter().map(OlsStats::fit));
    }
}

/// Reusable buffers for `plan_batch_into`. Each coordinator worker owns
/// one, so a steady-state batcher flush performs no per-request `String`
/// clones and reuses every intermediate numeric buffer across flushes
/// (what remains per flush: one request-tuple `Vec` of borrowed names,
/// plus the returned plans themselves).
#[derive(Debug, Default)]
pub struct PlanScratch {
    models: Vec<LinModel>,
    xq: Vec<f64>,
    scale: Vec<f64>,
    known: Vec<bool>,
    flat: Vec<f64>,
    /// Assembled plans, in request order, after `plan_batch_into`.
    pub plans: Vec<StepPlan>,
}

/// Model store + pure prediction logic, shared by the threaded service
/// and the batch experiment path.
pub struct ModelStore {
    pub k: usize,
    pub capacity_gb: f64,
    backend: Backend,
    models: std::collections::BTreeMap<String, TaskModels>,
}

impl ModelStore {
    pub fn new(k: usize, capacity_gb: f64, backend: Backend) -> Self {
        ModelStore { k, capacity_gb, backend, models: Default::default() }
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    pub fn has_task(&self, task: &str) -> bool {
        self.models.contains_key(task)
    }

    pub fn tasks(&self) -> Vec<String> {
        self.models.keys().cloned().collect()
    }

    /// Fold one execution's aligned segment rows into the task's
    /// accumulators WITHOUT refitting the closed forms. Returns whether
    /// anything was folded (sample-less executions are no-ops).
    fn fold_observation(&mut self, task: &str, e: &Execution) -> bool {
        if e.samples.is_empty() {
            return false;
        }
        let k = self.k;
        // Steady state allocates no task-name String: only the first
        // observation of a task inserts a key.
        if !self.models.contains_key(task) {
            self.models.insert(task.to_string(), TaskModels::empty(k));
        }
        let tm = self.models.get_mut(task).expect("inserted above");
        let (starts, peaks) = KsPlus::aligned_rows(k, e);
        for j in 0..k {
            tm.stats[j].push(e.input_mb, starts[j]);
            tm.stats[k + j].push(e.input_mb, peaks[j]);
        }
        tm.fallback_peak = tm.fallback_peak.max(e.peak());
        tm.observed += 1;
        true
    }

    /// Fold ONE finished execution into the task's models: segments only
    /// the new execution (a single `get_segments` call) and updates the
    /// 2k sufficient-statistic accumulators + closed-form refits in O(k).
    /// History is never revisited. Returns `(folded, count)`: whether
    /// the execution was actually folded in (sample-less executions are
    /// ignored — nothing to segment) and the task's total observation
    /// count. `folded` is the single source of truth for "did the models
    /// change", so callers counting observations never drift from the
    /// store's skip policy.
    pub fn observe(&mut self, task: &str, e: &Execution) -> (bool, u64) {
        let folded = self.fold_observation(task, e);
        let k = self.k;
        match self.models.get_mut(task) {
            None => (false, 0),
            Some(tm) => {
                if folded {
                    tm.refit(k);
                }
                (folded, tm.observed)
            }
        }
    }

    /// Train (or retrain) one task from scratch: discards any prior
    /// state for the task and folds the history into fresh accumulators,
    /// refitting once at the end — bit-identical to streaming the same
    /// history through `observe` (the refit is a pure function of the
    /// accumulators). A history with nothing to learn from (empty, or
    /// containing only sample-less executions) keeps existing models
    /// (unchanged empty-history policy).
    pub fn train(&mut self, task: &str, history: &[Execution]) {
        if !history.iter().any(|e| !e.samples.is_empty()) {
            return;
        }
        self.models.remove(task);
        for e in history {
            self.fold_observation(task, e);
        }
        let k = self.k;
        if let Some(tm) = self.models.get_mut(task) {
            tm.refit(k);
        }
    }

    /// Plan a batch of requests with ONE backend predict call.
    /// Unknown tasks get a capacity-safe flat fallback. Convenience
    /// wrapper over `plan_batch_into` for callers without a scratch.
    pub fn plan_batch(&self, requests: &[(&str, f64)]) -> Vec<StepPlan> {
        let mut scratch = PlanScratch::default();
        self.plan_batch_into(requests, &mut scratch);
        scratch.plans
    }

    /// Allocation-lean batch planning: task names are borrowed and every
    /// intermediate buffer lives in the caller's reusable `scratch`;
    /// results land in `scratch.plans` in request order.
    pub fn plan_batch_into(&self, requests: &[(&str, f64)], s: &mut PlanScratch) {
        s.models.clear();
        s.xq.clear();
        s.scale.clear();
        s.known.clear();
        s.plans.clear();
        for (task, input) in requests {
            match self.models.get(*task) {
                None => s.known.push(false),
                Some(tm) => {
                    s.known.push(true);
                    for m in &tm.start_models {
                        s.models.push(*m);
                        s.xq.push(*input);
                        s.scale.push(TIME_UNDERPREDICT);
                    }
                    for m in &tm.peak_models {
                        s.models.push(*m);
                        s.xq.push(*input);
                        s.scale.push(MEM_OVERPREDICT);
                    }
                }
            }
        }
        self.backend.predict_into(&s.models, &s.xq, &s.scale, &mut s.flat);
        let mut off = 0usize;
        for i in 0..requests.len() {
            if !s.known[i] {
                // Absent from the store (known[i] was set under this
                // same &self borrow): nothing learned, serve the
                // capacity-safe flat default.
                let peak = self.capacity_gb / 4.0;
                s.plans.push(StepPlan::flat(peak.min(self.capacity_gb)));
                continue;
            }
            let starts = &s.flat[off..off + self.k];
            let peaks = &s.flat[off + self.k..off + 2 * self.k];
            off += 2 * self.k;
            // Offsets already applied via `scale`; pass identity here.
            s.plans.push(KsPlus::assemble_plan(starts, peaks, 1.0, 1.0, self.capacity_gb));
        }
    }

    /// KS+ retry strategy (Section II-C) for a reported OOM.
    pub fn on_failure(&self, prev: &StepPlan, fail_time: f64) -> StepPlan {
        // Stateless plan math: delegate to a throwaway KsPlus with our
        // capacity. (The strategy uses no trained state.)
        use crate::predictor::Predictor;
        KsPlus::new(self.k, self.capacity_gb).on_failure(prev, fail_time, 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predictor::Predictor;
    use crate::util::rng::Rng;

    fn two_phase_exec(input: f64, rng: &mut Rng) -> Execution {
        let d1 = ((input * 0.01) as usize).max(2);
        let d2 = ((input * 0.003) as usize).max(1);
        let mut s = vec![input * 0.0005; d1];
        s.extend(vec![input * 0.001; d2]);
        for v in s.iter_mut() {
            *v *= 1.0 - 0.01 * rng.f64();
        }
        Execution::new("bwa", input, 1.0, s)
    }

    #[test]
    fn backend_spec_availability_tracks_feature() {
        assert!(BackendSpec::Native.available());
        assert_eq!(BackendSpec::Pjrt(None).available(), cfg!(feature = "pjrt"));
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_spec_is_runtime_error_without_feature() {
        let err = BackendSpec::Pjrt(None).build().err().expect("must not build");
        let msg = format!("{err:#}");
        assert!(msg.contains("pjrt"), "unhelpful error: {msg}");
    }

    #[test]
    fn store_matches_ksplus_predictor() {
        let mut rng = Rng::new(1);
        let hist: Vec<Execution> =
            (0..30).map(|_| two_phase_exec(rng.uniform(2000.0, 12000.0), &mut rng)).collect();
        let mut store = ModelStore::new(2, 128.0, Backend::Native);
        store.train("bwa", &hist);
        let mut pred = KsPlus::new(2, 128.0);
        pred.train(&hist);
        let plans = store.plan_batch(&[("bwa", 8000.0)]);
        let want = pred.plan(8000.0);
        assert_eq!(plans[0].k(), want.k());
        for i in 0..want.k() {
            assert!((plans[0].starts[i] - want.starts[i]).abs() < 1e-9, "{plans:?} vs {want:?}");
            assert!((plans[0].peaks[i] - want.peaks[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn unknown_task_gets_fallback() {
        let store = ModelStore::new(2, 128.0, Backend::Native);
        let plans = store.plan_batch(&[("mystery", 100.0)]);
        assert_eq!(plans.len(), 1);
        assert_eq!(plans[0].k(), 1);
        assert!(plans[0].peaks[0] <= 128.0);
    }

    #[test]
    fn batch_of_mixed_tasks() {
        let mut rng = Rng::new(2);
        let hist: Vec<Execution> =
            (0..20).map(|_| two_phase_exec(rng.uniform(2000.0, 9000.0), &mut rng)).collect();
        let mut store = ModelStore::new(2, 128.0, Backend::Native);
        store.train("bwa", &hist);
        let reqs: Vec<(&str, f64)> =
            vec![("bwa", 4000.0), ("mystery", 1.0), ("bwa", 8000.0)];
        let plans = store.plan_batch(&reqs);
        assert_eq!(plans.len(), 3);
        assert!(plans[0].peaks.last() < plans[2].peaks.last());
        assert!(plans.iter().all(|p| p.is_valid()));
    }

    #[test]
    fn scratch_reuse_matches_fresh_plan_batch() {
        // plan_batch_into over a dirty, reused scratch must produce the
        // same plans as a fresh plan_batch call, batch after batch.
        let mut rng = Rng::new(9);
        let hist: Vec<Execution> =
            (0..20).map(|_| two_phase_exec(rng.uniform(2000.0, 9000.0), &mut rng)).collect();
        let mut store = ModelStore::new(3, 128.0, Backend::Native);
        store.train("bwa", &hist);
        let mut scratch = PlanScratch::default();
        for round in 0..4 {
            let reqs: Vec<(&str, f64)> = vec![
                ("bwa", 3000.0 + round as f64 * 500.0),
                ("mystery", 1.0),
                ("bwa", 9000.0 - round as f64 * 250.0),
            ];
            store.plan_batch_into(&reqs, &mut scratch);
            let fresh = store.plan_batch(&reqs);
            assert_eq!(scratch.plans, fresh, "round {round}");
        }
    }

    #[test]
    fn observe_fold_is_bit_identical_to_batch_train() {
        // The tentpole equivalence: batch train == fold of observe, with
        // exactly equal (not merely close) model outputs.
        let mut rng = Rng::new(4);
        let hist: Vec<Execution> =
            (0..25).map(|_| two_phase_exec(rng.uniform(2000.0, 12000.0), &mut rng)).collect();
        let mut batch = ModelStore::new(3, 128.0, Backend::Native);
        batch.train("bwa", &hist);
        let mut incr = ModelStore::new(3, 128.0, Backend::Native);
        for (i, e) in hist.iter().enumerate() {
            assert_eq!(incr.observe("bwa", e), (true, i as u64 + 1));
        }
        for input in [1500.0, 4000.0, 8000.0, 13000.0] {
            let a = batch.plan_batch(&[("bwa", input)]);
            let b = incr.plan_batch(&[("bwa", input)]);
            assert_eq!(a[0].starts, b[0].starts, "input {input}");
            assert_eq!(a[0].peaks, b[0].peaks, "input {input}");
        }
    }

    #[test]
    fn observe_interleaved_matches_scratch_retrained_ksplus() {
        // Observing one execution at a time must track a KsPlus predictor
        // retrained from scratch on the same prefix, within 1e-9.
        let mut rng = Rng::new(6);
        let hist: Vec<Execution> =
            (0..16).map(|_| two_phase_exec(rng.uniform(2000.0, 12000.0), &mut rng)).collect();
        let mut store = ModelStore::new(2, 128.0, Backend::Native);
        for (i, e) in hist.iter().enumerate() {
            store.observe("bwa", e);
            let mut scratch = KsPlus::new(2, 128.0);
            scratch.train(&hist[..=i]);
            let want = scratch.plan(6000.0);
            let got = store.plan_batch(&[("bwa", 6000.0)]);
            assert_eq!(got[0].k(), want.k(), "after {} observations", i + 1);
            for j in 0..want.k() {
                assert!((got[0].starts[j] - want.starts[j]).abs() < 1e-9);
                assert!((got[0].peaks[j] - want.peaks[j]).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn observe_segments_only_the_new_execution() {
        // The O(k) claim, asserted by op count: one observe = exactly one
        // get_segments call, no matter how much history is accumulated.
        use crate::segments::algorithm::SEG_CALLS;
        let mut rng = Rng::new(8);
        let hist: Vec<Execution> =
            (0..40).map(|_| two_phase_exec(rng.uniform(2000.0, 9000.0), &mut rng)).collect();
        let mut store = ModelStore::new(4, 128.0, Backend::Native);
        store.train("bwa", &hist);
        for e in hist.iter().take(5) {
            let before = SEG_CALLS.with(|c| c.get());
            store.observe("bwa", e);
            let after = SEG_CALLS.with(|c| c.get());
            assert_eq!(after - before, 1, "observe re-segmented history");
        }
        // Batch train over n executions segments each exactly once.
        let before = SEG_CALLS.with(|c| c.get());
        store.train("bwa", &hist);
        let after = SEG_CALLS.with(|c| c.get());
        assert_eq!(after - before, hist.len() as u64);
    }

    #[test]
    fn observe_ignores_empty_executions() {
        let mut store = ModelStore::new(2, 128.0, Backend::Native);
        assert_eq!(
            store.observe("bwa", &Execution::new("bwa", 100.0, 1.0, vec![])),
            (false, 0)
        );
        assert!(!store.has_task("bwa"));
        let mut rng = Rng::new(10);
        store.observe("bwa", &two_phase_exec(4000.0, &mut rng));
        assert_eq!(
            store.observe("bwa", &Execution::new("bwa", 100.0, 1.0, vec![])),
            (false, 1)
        );
        assert!(store.plan_batch(&[("bwa", 4000.0)])[0].is_valid());
    }

    #[test]
    fn train_with_nothing_to_learn_keeps_existing_models() {
        // A retrain whose history carries no usable samples must not
        // delete the task's learned models (same policy as an empty
        // history) — neither fully empty nor all-sample-less histories.
        let mut rng = Rng::new(12);
        let hist: Vec<Execution> =
            (0..10).map(|_| two_phase_exec(rng.uniform(2000.0, 9000.0), &mut rng)).collect();
        let mut store = ModelStore::new(2, 128.0, Backend::Native);
        store.train("bwa", &hist);
        let before = store.plan_batch(&[("bwa", 5000.0)]);
        store.train("bwa", &[]);
        store.train("bwa", &[Execution::new("bwa", 100.0, 1.0, vec![])]);
        assert!(store.has_task("bwa"));
        let after = store.plan_batch(&[("bwa", 5000.0)]);
        assert_eq!(before, after);
    }

    #[test]
    fn failure_rescaling_delegates_to_ksplus() {
        let store = ModelStore::new(2, 128.0, Backend::Native);
        let prev = StepPlan::new(vec![0.0, 100.0], vec![2.0, 8.0]);
        let next = store.on_failure(&prev, 60.0);
        assert_eq!(next.starts, vec![0.0, 60.0]);
    }

    #[test]
    fn retrain_replaces_models() {
        let mut rng = Rng::new(3);
        let h1: Vec<Execution> =
            (0..10).map(|_| two_phase_exec(3000.0, &mut rng)).collect();
        let h2: Vec<Execution> =
            (0..10).map(|_| two_phase_exec(9000.0, &mut rng)).collect();
        let mut store = ModelStore::new(2, 128.0, Backend::Native);
        store.train("bwa", &h1);
        let p1 = store.plan_batch(&[("bwa", 5000.0)]);
        store.train("bwa", &h2);
        let p2 = store.plan_batch(&[("bwa", 5000.0)]);
        // Different training data -> different (still valid) plans.
        assert!(p1[0].is_valid() && p2[0].is_valid());
    }
}
