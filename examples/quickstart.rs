//! Quickstart: train KS+ on one task's history, predict a plan for a new
//! instance, and survive an OOM with the segment-rescaling retry.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use ksplus::predictor::{by_name, Predictor};
use ksplus::sim::run_task;
use ksplus::trace::workflow::Workflow;
use ksplus::trace::split_train_test;
use ksplus::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    // 1. Get some task history. Here: synthetic BWA traces from the
    //    eager workflow generator (or load your own CSV via trace::io).
    let trace = Workflow::eager().generate(42, 200);
    let bwa = trace.task("bwa").expect("bwa task");
    let (train, test) = split_train_test(bwa, 0.5, &mut Rng::new(1));
    println!("BWA: {} training / {} test executions", train.len(), test.len());

    // 2. Train KS+ with k = 4 variable segments on a 128 GB node.
    let mut ksplus = by_name("ksplus", 4, 128.0).expect("method");
    ksplus.train(&train);

    // 3. Predict an allocation plan for a new input size.
    let e = &test[0];
    let plan = ksplus.plan(e.input_mb);
    println!("\ninput {:.0} MB -> plan with {} segments:", e.input_mb, plan.k());
    for i in 0..plan.k() {
        println!("  from {:>6.0} s allocate {:>5.2} GB", plan.starts[i], plan.peaks[i]);
    }

    // 4. Run the whole test set through the OOM/retry simulator and
    //    compare wastage against a peak-only baseline.
    let mut improved = by_name("ppm-improved", 4, 128.0).unwrap();
    improved.train(&train);
    let mut w_ks = 0.0;
    let mut w_ppm = 0.0;
    let mut retries = 0usize;
    for e in &test {
        let (o, _) = run_task(ksplus.as_ref(), e, 10);
        assert!(o.success);
        w_ks += o.wastage_gbs;
        retries += o.attempts - 1;
        w_ppm += run_task(improved.as_ref(), e, 10).0.wastage_gbs;
    }
    println!("\ntest-set wastage:");
    println!("  KS+          : {:>8.0} GBs ({} retries)", w_ks, retries);
    println!("  PPM-Improved : {:>8.0} GBs", w_ppm);
    println!("  reduction    : {:.0}%", (1.0 - w_ks / w_ppm) * 100.0);
    Ok(())
}
