//! Segment model: monotonically increasing step functions over time.
//!
//! A `StepPlan` is the allocation strategy KS+ produces: `k` segments,
//! segment `i` starting at `starts[i]` (seconds, `starts[0] == 0`) with
//! allocation `peaks[i]` GB, monotone non-decreasing (Section II-A of the
//! paper: monotonicity avoids failures from releasing memory too early).
//! Beyond the last start the final peak holds forever, so a plan is total
//! over time even when the task runs longer than predicted.

pub mod algorithm;

use crate::trace::Execution;

/// Monotone step-function allocation plan.
#[derive(Debug, Clone, PartialEq)]
pub struct StepPlan {
    /// Segment start times, seconds; starts[0] == 0, strictly increasing.
    pub starts: Vec<f64>,
    /// Per-segment allocation, GB; non-decreasing.
    pub peaks: Vec<f64>,
}

impl StepPlan {
    pub fn new(starts: Vec<f64>, peaks: Vec<f64>) -> StepPlan {
        assert_eq!(starts.len(), peaks.len());
        assert!(!starts.is_empty(), "plan needs at least one segment");
        StepPlan { starts, peaks }
    }

    /// Single-segment (peak-only) plan — what all peak-prediction
    /// baselines produce.
    pub fn flat(peak: f64) -> StepPlan {
        StepPlan { starts: vec![0.0], peaks: vec![peak] }
    }

    pub fn k(&self) -> usize {
        self.starts.len()
    }

    /// Allocation at time `t` (seconds).
    pub fn alloc_at(&self, t: f64) -> f64 {
        self.peaks[self.segment_at(t)]
    }

    /// Segment index active at time `t`: the last segment whose start is
    /// <= t (before t=0 this clamps to the first). O(log k) binary
    /// search — `starts` is strictly increasing.
    pub fn segment_at(&self, t: f64) -> usize {
        self.starts.partition_point(|&s| s <= t).saturating_sub(1)
    }

    /// Structural validity: starts strictly increasing from 0, peaks
    /// non-decreasing and positive.
    pub fn is_valid(&self) -> bool {
        if self.starts.is_empty() || self.starts[0] != 0.0 {
            return false;
        }
        let starts_ok = self.starts.windows(2).all(|w| w[0] < w[1]);
        let peaks_ok = self.peaks.windows(2).all(|w| w[0] <= w[1] + 1e-12);
        let pos = self.peaks.iter().all(|&p| p > 0.0 && p.is_finite());
        starts_ok && peaks_ok && pos
    }

    /// Whether the plan covers the execution: alloc(t) >= usage(t) at
    /// every sample (strictly: usage must not exceed allocation).
    ///
    /// Single forward sweep, O(n + k): sample times only increase, so
    /// the active-segment cursor never rewinds (vs. an O(k) `alloc_at`
    /// scan per sample). Same for `first_oom` and `wastage_gbs` below —
    /// these three dominate the simulators and every experiment.
    pub fn covers(&self, e: &Execution) -> bool {
        let mut seg = 0usize;
        for (i, &u) in e.samples.iter().enumerate() {
            let t = i as f64 * e.dt;
            while seg + 1 < self.starts.len() && self.starts[seg + 1] <= t {
                seg += 1;
            }
            if self.peaks[seg] < u {
                return false;
            }
        }
        true
    }

    /// First failure time (seconds) if the execution exceeds the plan,
    /// plus the usage at that moment. Single sweep, O(n + k).
    pub fn first_oom(&self, e: &Execution) -> Option<(f64, f64)> {
        let mut seg = 0usize;
        for (i, &u) in e.samples.iter().enumerate() {
            let t = i as f64 * e.dt;
            while seg + 1 < self.starts.len() && self.starts[seg + 1] <= t {
                seg += 1;
            }
            if u > self.peaks[seg] {
                return Some((t, u));
            }
        }
        None
    }

    /// Integral of the allocation over [0, horizon], GB*s.
    pub fn alloc_gbs(&self, horizon: f64) -> f64 {
        let mut total = 0.0;
        for i in 0..self.starts.len() {
            let s = self.starts[i].min(horizon);
            let e = if i + 1 < self.starts.len() { self.starts[i + 1].min(horizon) } else { horizon };
            if e > s {
                total += self.peaks[i] * (e - s);
            }
        }
        total
    }

    /// Wastage vs a *successful* execution: sum over samples of
    /// (alloc - used) * dt. Assumes `covers(e)`; failure-attempt cost is
    /// accounted by the simulator (`sim::run_task`). Single sweep,
    /// O(n + k).
    pub fn wastage_gbs(&self, e: &Execution) -> f64 {
        let mut seg = 0usize;
        let mut total = 0.0f64;
        for (i, &u) in e.samples.iter().enumerate() {
            let t = i as f64 * e.dt;
            while seg + 1 < self.starts.len() && self.starts[seg + 1] <= t {
                seg += 1;
            }
            total += (self.peaks[seg] - u).max(0.0);
        }
        total * e.dt
    }

    /// Final (highest) peak, or `default` for a degenerate empty plan.
    ///
    /// Retry strategies scale the previous attempt's last peak; routing
    /// them through this accessor keeps the degenerate-plan policy in one
    /// place instead of a `last().unwrap()` panic at every call site.
    pub fn last_peak_or(&self, default: f64) -> f64 {
        self.peaks.last().copied().unwrap_or(default)
    }

    /// Clamp every peak to at most `cap` (node capacity), preserving shape.
    pub fn clamped(&self, cap: f64) -> StepPlan {
        StepPlan {
            starts: self.starts.clone(),
            peaks: self.peaks.iter().map(|p| p.min(cap)).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::run_prop;

    fn plan2() -> StepPlan {
        StepPlan::new(vec![0.0, 10.0], vec![2.0, 5.0])
    }

    #[test]
    fn alloc_at_steps() {
        let p = plan2();
        assert_eq!(p.alloc_at(0.0), 2.0);
        assert_eq!(p.alloc_at(9.99), 2.0);
        assert_eq!(p.alloc_at(10.0), 5.0);
        assert_eq!(p.alloc_at(1e9), 5.0);
        assert_eq!(p.alloc_at(-1.0), 2.0);
    }

    #[test]
    fn segment_at_matches_alloc() {
        let p = StepPlan::new(vec![0.0, 5.0, 20.0], vec![1.0, 2.0, 3.0]);
        assert_eq!(p.segment_at(0.0), 0);
        assert_eq!(p.segment_at(5.0), 1);
        assert_eq!(p.segment_at(19.0), 1);
        assert_eq!(p.segment_at(25.0), 2);
    }

    #[test]
    fn validity_checks() {
        assert!(plan2().is_valid());
        assert!(!StepPlan::new(vec![1.0, 2.0], vec![1.0, 2.0]).is_valid()); // no 0 start
        assert!(!StepPlan::new(vec![0.0, 0.0], vec![1.0, 2.0]).is_valid()); // dup start
        assert!(!StepPlan::new(vec![0.0, 1.0], vec![2.0, 1.0]).is_valid()); // decreasing
        assert!(StepPlan::flat(4.0).is_valid());
    }

    #[test]
    fn covers_and_first_oom() {
        let e = Execution::new("t", 1.0, 1.0, vec![1.0, 1.5, 4.0, 4.5]);
        let good = StepPlan::new(vec![0.0, 2.0], vec![2.0, 5.0]);
        assert!(good.covers(&e));
        assert_eq!(good.first_oom(&e), None);
        let bad = StepPlan::new(vec![0.0, 3.0], vec![2.0, 5.0]);
        assert!(!bad.covers(&e));
        let (t, u) = bad.first_oom(&e).unwrap();
        assert_eq!(t, 2.0);
        assert_eq!(u, 4.0);
    }

    #[test]
    fn alloc_gbs_piecewise() {
        let p = plan2();
        // 10s at 2.0 + 5s at 5.0
        assert!((p.alloc_gbs(15.0) - 45.0).abs() < 1e-12);
        // horizon inside first segment
        assert!((p.alloc_gbs(4.0) - 8.0).abs() < 1e-12);
        assert_eq!(p.alloc_gbs(0.0), 0.0);
    }

    #[test]
    fn wastage_exact() {
        let e = Execution::new("t", 1.0, 2.0, vec![1.0, 1.0, 4.0]);
        let p = StepPlan::new(vec![0.0, 4.0], vec![2.0, 5.0]);
        // samples at t=0,2,4; alloc 2,2,5; waste (1+1+1)*2 = 6
        assert!((p.wastage_gbs(&e) - 6.0).abs() < 1e-12);
    }

    #[test]
    fn clamp_preserves_validity() {
        let p = StepPlan::new(vec![0.0, 5.0], vec![100.0, 200.0]);
        let c = p.clamped(128.0);
        assert_eq!(c.peaks, vec![100.0, 128.0]);
        assert!(c.is_valid());
    }

    #[test]
    fn prop_alloc_is_monotone_over_time() {
        run_prop("plan_monotone_time", 200, |rng| {
            let k = 1 + rng.below(6);
            let mut starts = vec![0.0];
            let mut peaks = vec![rng.uniform(0.1, 4.0)];
            for _ in 1..k {
                starts.push(starts.last().unwrap() + rng.uniform(0.5, 30.0));
                peaks.push(peaks.last().unwrap() + rng.uniform(0.0, 4.0));
            }
            let p = StepPlan::new(starts, peaks);
            assert!(p.is_valid());
            let mut prev = 0.0f64;
            for i in 0..100 {
                let a = p.alloc_at(i as f64 * 1.3);
                assert!(a + 1e-12 >= prev, "alloc decreased over time");
                prev = a;
            }
        });
    }

    #[test]
    fn prop_sweep_matches_alloc_at_reference() {
        // covers/first_oom/wastage_gbs are single cursor sweeps; they
        // must agree exactly with the per-sample alloc_at definition.
        run_prop("plan_sweep_reference", 200, |rng| {
            let k = 1 + rng.below(6);
            let mut starts = vec![0.0];
            let mut peaks = vec![rng.uniform(0.1, 4.0)];
            for _ in 1..k {
                starts.push(starts.last().unwrap() + rng.uniform(0.5, 30.0));
                peaks.push(peaks.last().unwrap() + rng.uniform(0.0, 4.0));
            }
            let p = StepPlan::new(starts, peaks);
            let n = rng.below(80);
            let dt = rng.uniform(0.1, 3.0);
            let samples: Vec<f64> = (0..n).map(|_| rng.uniform(0.0, 10.0)).collect();
            let e = Execution::new("t", 100.0, dt, samples);

            let ref_covers = e
                .samples
                .iter()
                .enumerate()
                .all(|(i, &u)| p.alloc_at(i as f64 * e.dt) >= u);
            assert_eq!(p.covers(&e), ref_covers);

            let ref_oom = e.samples.iter().enumerate().find_map(|(i, &u)| {
                let t = i as f64 * e.dt;
                (u > p.alloc_at(t)).then_some((t, u))
            });
            assert_eq!(p.first_oom(&e), ref_oom);

            let ref_wastage: f64 = e
                .samples
                .iter()
                .enumerate()
                .map(|(i, &u)| (p.alloc_at(i as f64 * e.dt) - u).max(0.0))
                .sum::<f64>()
                * e.dt;
            // Bit-identical: same additions in the same order.
            assert_eq!(p.wastage_gbs(&e), ref_wastage);
        });
    }

    #[test]
    fn sweep_handles_empty_execution() {
        let p = plan2();
        let e = Execution::new("t", 1.0, 1.0, vec![]);
        assert!(p.covers(&e));
        assert_eq!(p.first_oom(&e), None);
        assert_eq!(p.wastage_gbs(&e), 0.0);
    }

    #[test]
    fn prop_alloc_gbs_matches_riemann_sum() {
        run_prop("plan_gbs_riemann", 100, |rng| {
            let k = 1 + rng.below(5);
            let mut starts = vec![0.0];
            let mut peaks = vec![rng.uniform(0.1, 4.0)];
            for _ in 1..k {
                starts.push(starts.last().unwrap() + rng.uniform(1.0, 20.0));
                peaks.push(peaks.last().unwrap() + rng.uniform(0.0, 2.0));
            }
            let p = StepPlan::new(starts.clone(), peaks);
            let horizon = starts.last().unwrap() + rng.uniform(0.0, 40.0);
            let dt = 1e-3;
            let n = (horizon / dt) as usize;
            let riemann: f64 = (0..n).map(|i| p.alloc_at(i as f64 * dt) * dt).sum();
            let exact = p.alloc_gbs(horizon);
            assert!(
                (riemann - exact).abs() < exact.max(1.0) * 1e-2,
                "riemann {riemann} vs exact {exact}"
            );
        });
    }
}
