//! `artifacts/manifest.json` parsing (written by `python/compile/aot.py`).

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Artifact inventory and bucket shapes.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Fit/fit_predict/wastage batch bucket.
    pub fit_b: usize,
    /// Observation-axis bucket.
    pub fit_n: usize,
    /// Predict batch bucket.
    pub predict_b: usize,
    /// Max plan segments for the plan_wastage artifact.
    pub plan_k: usize,
    /// Pallas batch block size (for roofline estimates, not execution).
    pub block_b: usize,
    /// (name, file) pairs.
    pub entries: Vec<(String, String)>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {path:?}"))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).context("parsing manifest.json")?;
        let b = j.get("buckets").context("manifest missing 'buckets'")?;
        let get = |k: &str| -> Result<usize> {
            b.get(k).and_then(Json::as_usize).with_context(|| format!("bucket '{k}'"))
        };
        let entries = j
            .get("entries")
            .and_then(Json::as_arr)
            .context("manifest missing 'entries'")?
            .iter()
            .map(|e| -> Result<(String, String)> {
                let name = e.get("name").and_then(Json::as_str).context("entry name")?;
                let file = e.get("file").and_then(Json::as_str).context("entry file")?;
                Ok((name.to_string(), file.to_string()))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest {
            fit_b: get("fit_b")?,
            fit_n: get("fit_n")?,
            predict_b: get("predict_b")?,
            // Optional for manifests written before the plan_wastage
            // artifact existed.
            plan_k: b.get("plan_k").and_then(Json::as_usize).unwrap_or(8),
            block_b: j.get("block_b").and_then(Json::as_usize).unwrap_or(128),
            entries,
        })
    }

    /// File name of the entry whose name starts with `prefix` and is the
    /// exact kernel kind (`fit` must not match `fit_predict`). With
    /// multiple observation buckets, returns the largest.
    pub fn entry_file(&self, kind: &str) -> Result<String> {
        let files = self.entry_files(kind);
        files
            .into_iter()
            .max_by_key(|(n, _)| *n)
            .map(|(_, f)| f)
            .with_context(|| format!("no artifact entry of kind '{kind}'"))
    }

    /// All (observation-bucket, file) variants of a kernel kind, sorted
    /// ascending by bucket size. Kinds without an `_n{N}` suffix report
    /// bucket 0.
    pub fn entry_files(&self, kind: &str) -> Vec<(usize, String)> {
        let mut out = Vec::new();
        for (name, file) in &self.entries {
            let rest = match name.strip_prefix(kind) {
                Some(r) => r,
                None => continue,
            };
            // After the kind, only the bucket suffix may follow.
            if !rest.starts_with("_b") {
                continue;
            }
            let n = rest
                .split("_n")
                .nth(1)
                .and_then(|s| s.split('_').next())
                .and_then(|s| s.parse::<usize>().ok())
                .unwrap_or(0);
            out.push((n, file.clone()));
        }
        out.sort_by_key(|(n, _)| *n);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "buckets": {"fit_b": 256, "fit_n": 512, "predict_b": 1024, "plan_k": 8},
      "block_b": 128,
      "entries": [
        {"name": "fit_b256_n512", "file": "fit_b256_n512.hlo.txt"},
        {"name": "predict_b1024", "file": "predict_b1024.hlo.txt"},
        {"name": "fit_predict_b256_n512", "file": "fit_predict_b256_n512.hlo.txt"},
        {"name": "wastage_b256_n512", "file": "wastage_b256_n512.hlo.txt"},
        {"name": "plan_wastage_b256_n512_k8", "file": "plan_wastage_b256_n512_k8.hlo.txt"}
      ]
    }"#;

    #[test]
    fn parses_buckets_and_entries() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!((m.fit_b, m.fit_n, m.predict_b, m.block_b), (256, 512, 1024, 128));
        assert_eq!(m.plan_k, 8);
        assert_eq!(m.entries.len(), 5);
    }

    #[test]
    fn plan_k_defaults_when_missing() {
        let old = r#"{"buckets": {"fit_b": 1, "fit_n": 1, "predict_b": 1}, "entries": []}"#;
        let m = Manifest::parse(old).unwrap();
        assert_eq!(m.plan_k, 8);
    }

    #[test]
    fn entry_kind_disambiguation() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.entry_file("fit").unwrap(), "fit_b256_n512.hlo.txt");
        assert_eq!(m.entry_file("fit_predict").unwrap(), "fit_predict_b256_n512.hlo.txt");
        assert_eq!(m.entry_file("wastage").unwrap(), "wastage_b256_n512.hlo.txt");
        assert!(m.entry_file("nonexistent").is_err());
    }

    #[test]
    fn rejects_missing_buckets() {
        assert!(Manifest::parse(r#"{"entries": []}"#).is_err());
        assert!(Manifest::parse("not json").is_err());
    }

    #[test]
    fn real_manifest_if_built() {
        let dir = crate::runtime::default_artifacts_dir();
        let p = dir.join("manifest.json");
        if p.exists() {
            let m = Manifest::load(&p).unwrap();
            assert!(m.fit_b >= 1 && m.fit_n >= 1 && m.predict_b >= 1);
            assert!(m.entry_file("fit").is_ok());
        }
    }
}
