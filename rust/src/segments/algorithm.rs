//! Algorithm 1: greedy variable-size segmentation of one memory series,
//! plus an exact O(n^2 k) DP used as an ablation baseline.
//!
//! Step 1 builds the minimal *monotone envelope* of the series: scanning
//! front to back, every sample that does not exceed the current segment's
//! peak merges into it; a larger sample opens a new segment ("merge every
//! segment with its predecessor if its peak is smaller than the
//! predecessor's"). The result is the running-max step function — the
//! tightest monotonically increasing upper bound of the series.
//!
//! Step 2 greedily merges adjacent segments until only `k` remain, always
//! removing the merge with the smallest introduced error
//! `e_i = (P_{i+1} - P_i) * S_i` (Eq. 1): merging segment `i` into its
//! successor re-allocates `S_i` samples at the higher peak `P_{i+1}`.
//!
//! The merge loop runs in O(m log m) over the m envelope runs: runs live
//! in a neighbor-linked list (`prev`/`next` index arrays) and merge
//! candidates in a min-heap keyed on (error, run id), invalidated
//! *lazily* — a merge changes exactly two candidates (the predecessor's
//! error, whose successor peak changed, and the merged run's error, whose
//! size changed), so those two are re-pushed with a bumped version and
//! stale heap entries are skipped on pop. Ties break on the lower run id,
//! which equals the lower current position, so the merge sequence — and
//! therefore the result, bit for bit — matches the original quadratic
//! rescan loop (`get_segments_quadratic`, retained as the equivalence
//! oracle and bench baseline).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::segments::StepPlan;

/// Test-only counter of `get_segments` calls on the current thread, used
/// to assert that the coordinator's incremental `observe` segments
/// exactly one execution (no re-segmentation of history).
#[cfg(test)]
thread_local! {
    pub(crate) static SEG_CALLS: std::cell::Cell<u64> = const { std::cell::Cell::new(0) };
}

/// Segmentation result in sample units: `sizes[i]` samples at `peaks[i]`.
#[derive(Debug, Clone, PartialEq)]
pub struct Segmentation {
    pub sizes: Vec<usize>,
    pub peaks: Vec<f64>,
}

impl Segmentation {
    /// Convert to a time-domain plan given the sampling interval.
    pub fn to_plan(&self, dt: f64) -> StepPlan {
        let mut starts = Vec::with_capacity(self.sizes.len());
        let mut acc = 0usize;
        for &s in &self.sizes {
            starts.push(acc as f64 * dt);
            acc += s;
        }
        StepPlan::new(starts, self.peaks.clone())
    }

    /// Segment start *offsets* in samples.
    pub fn start_offsets(&self) -> Vec<usize> {
        let mut out = Vec::with_capacity(self.sizes.len());
        let mut acc = 0;
        for &s in &self.sizes {
            out.push(acc);
            acc += s;
        }
        out
    }

    /// Total extra GB*samples this segmentation allocates above the
    /// monotone envelope of `samples`.
    pub fn envelope_error(&self, samples: &[f64]) -> f64 {
        let env = monotone_envelope(samples);
        let mut err = 0.0;
        let mut idx = 0usize;
        for (seg, &size) in self.sizes.iter().enumerate() {
            for _ in 0..size {
                err += self.peaks[seg] - env[idx];
                idx += 1;
            }
        }
        err
    }
}

/// Running-max envelope of a series.
pub fn monotone_envelope(samples: &[f64]) -> Vec<f64> {
    let mut out = Vec::with_capacity(samples.len());
    let mut m = f64::NEG_INFINITY;
    for &s in samples {
        m = m.max(s);
        out.push(m);
    }
    out
}

/// A pending merge of run `id` into its successor, costing `err`.
/// Entries are compared (error, id, version) ascending; `ver` lets stale
/// entries be recognized and skipped after the run's error changed.
struct MergeCand {
    err: f64,
    id: usize,
    ver: u32,
}

impl PartialEq for MergeCand {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}
impl Eq for MergeCand {}
impl PartialOrd for MergeCand {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for MergeCand {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.err
            .total_cmp(&other.err)
            .then(self.id.cmp(&other.id))
            .then(self.ver.cmp(&other.ver))
    }
}

/// Step 1 shared by both merge loops: the monotone envelope as
/// (size, peak) runs.
fn envelope_runs(samples: &[f64]) -> (Vec<usize>, Vec<f64>) {
    let mut sizes: Vec<usize> = vec![1];
    let mut peaks: Vec<f64> = vec![samples[0]];
    for &m in &samples[1..] {
        if m <= *peaks.last().unwrap() {
            *sizes.last_mut().unwrap() += 1;
        } else {
            sizes.push(1);
            peaks.push(m);
        }
    }
    (sizes, peaks)
}

/// Algorithm 1 (paper): greedy `k`-segmentation of a memory series, in
/// O(n + m log m) where m is the number of envelope steps.
///
/// Returns fewer than `k` segments when the envelope has fewer steps.
/// Panics on an empty series.
pub fn get_segments(samples: &[f64], k: usize) -> Segmentation {
    assert!(!samples.is_empty(), "cannot segment an empty series");
    assert!(k >= 1);
    #[cfg(test)]
    SEG_CALLS.with(|c| c.set(c.get() + 1));
    let (mut sizes, peaks) = envelope_runs(samples);
    let m = peaks.len();
    if m <= k {
        return Segmentation { sizes, peaks };
    }
    // Step 2: greedy merges, smallest e_i = (P_{i+1} - P_i) * S_i first,
    // over a neighbor-linked list with a lazily invalidated min-heap.
    const NONE: usize = usize::MAX;
    let mut prev: Vec<usize> = (0..m).map(|i| if i == 0 { NONE } else { i - 1 }).collect();
    let mut next: Vec<usize> = (0..m).map(|i| if i + 1 == m { NONE } else { i + 1 }).collect();
    let mut alive = vec![true; m];
    let mut ver = vec![0u32; m];
    let mut heap: BinaryHeap<Reverse<MergeCand>> = BinaryHeap::with_capacity(2 * m);
    for i in 0..m - 1 {
        heap.push(Reverse(MergeCand {
            err: (peaks[i + 1] - peaks[i]) * sizes[i] as f64,
            id: i,
            ver: 0,
        }));
    }
    let mut remaining = m;
    while remaining > k {
        let Reverse(cand) = heap.pop().expect("candidate exists while >k runs remain");
        let i = cand.id;
        if !alive[i] || ver[i] != cand.ver {
            continue; // stale: the run died or its error was re-pushed
        }
        // Merge run i into its successor. A current-version candidate
        // always has a live successor: the tail run never gets one, and
        // any change to a run's successor or size bumps its version.
        let n = next[i];
        debug_assert!(n != NONE && alive[n]);
        sizes[n] += sizes[i];
        alive[i] = false;
        remaining -= 1;
        let p = prev[i];
        if p != NONE {
            next[p] = n;
        }
        prev[n] = p;
        // Exactly two candidates changed: p's (successor peak is now
        // P_n) and n's (its size grew).
        if p != NONE {
            ver[p] += 1;
            heap.push(Reverse(MergeCand {
                err: (peaks[n] - peaks[p]) * sizes[p] as f64,
                id: p,
                ver: ver[p],
            }));
        }
        let nn = next[n];
        if nn != NONE {
            ver[n] += 1;
            heap.push(Reverse(MergeCand {
                err: (peaks[nn] - peaks[n]) * sizes[n] as f64,
                id: n,
                ver: ver[n],
            }));
        }
    }
    // Surviving runs, in original order (ids are envelope positions).
    let mut out_sizes = Vec::with_capacity(remaining);
    let mut out_peaks = Vec::with_capacity(remaining);
    for i in 0..m {
        if alive[i] {
            out_sizes.push(sizes[i]);
            out_peaks.push(peaks[i]);
        }
    }
    Segmentation { sizes: out_sizes, peaks: out_peaks }
}

/// The original quadratic merge loop (full rescan + `Vec::remove` per
/// merge), retained verbatim as the equivalence oracle for the heap
/// implementation and as the bench baseline (`cargo bench --bench
/// hotpath`). Not on any hot path.
pub fn get_segments_quadratic(samples: &[f64], k: usize) -> Segmentation {
    assert!(!samples.is_empty(), "cannot segment an empty series");
    assert!(k >= 1);
    let (mut sizes, mut peaks) = envelope_runs(samples);
    while peaks.len() > k {
        let mut best = 0usize;
        let mut best_e = f64::INFINITY;
        for i in 0..peaks.len() - 1 {
            let e = (peaks[i + 1] - peaks[i]) * sizes[i] as f64;
            if e < best_e {
                best_e = e;
                best = i;
            }
        }
        sizes[best + 1] += sizes[best];
        sizes.remove(best);
        peaks.remove(best);
    }
    Segmentation { sizes, peaks }
}

/// Exact DP segmentation minimising total over-allocation above the
/// monotone envelope with at most `k` segments. O(n^2 k) — used only by
/// the greedy-vs-optimal ablation (DESIGN.md design-choice bench), not on
/// any hot path.
pub fn optimal_segments(samples: &[f64], k: usize) -> Segmentation {
    assert!(!samples.is_empty());
    assert!(k >= 1);
    let env = monotone_envelope(samples);
    let n = env.len();
    let k = k.min(n);
    // Collapse equal runs first: segment boundaries only make sense at
    // envelope steps.
    let mut run_sizes: Vec<usize> = vec![1];
    let mut run_peaks: Vec<f64> = vec![env[0]];
    for &v in &env[1..] {
        if v == *run_peaks.last().unwrap() {
            *run_sizes.last_mut().unwrap() += 1;
        } else {
            run_sizes.push(1);
            run_peaks.push(v);
        }
    }
    let m = run_peaks.len();
    let k = k.min(m);
    // cost(a, b): runs a..=b as one segment at peak run_peaks[b].
    let mut prefix_gbsamples = vec![0.0f64; m + 1]; // sum(size*peak)
    let mut prefix_sizes = vec![0usize; m + 1];
    for i in 0..m {
        prefix_gbsamples[i + 1] = prefix_gbsamples[i] + run_sizes[i] as f64 * run_peaks[i];
        prefix_sizes[i + 1] = prefix_sizes[i] + run_sizes[i];
    }
    let cost = |a: usize, b: usize| -> f64 {
        let sz = (prefix_sizes[b + 1] - prefix_sizes[a]) as f64;
        sz * run_peaks[b] - (prefix_gbsamples[b + 1] - prefix_gbsamples[a])
    };
    // dp[j][b] = min cost covering runs 0..=b with j+1 segments.
    let mut dp = vec![vec![f64::INFINITY; m]; k];
    let mut arg = vec![vec![0usize; m]; k];
    for b in 0..m {
        dp[0][b] = cost(0, b);
    }
    for j in 1..k {
        for b in j..m {
            for a in j..=b {
                let c = dp[j - 1][a - 1] + cost(a, b);
                if c < dp[j][b] {
                    dp[j][b] = c;
                    arg[j][b] = a;
                }
            }
        }
    }
    // Pick the best segment count <= k (more segments never hurt).
    let mut best_j = 0;
    for j in 0..k {
        if dp[j][m - 1] < dp[best_j][m - 1] - 1e-15 {
            best_j = j;
        }
    }
    // Backtrack.
    let mut bounds = Vec::new();
    let mut b = m - 1;
    let mut j = best_j;
    loop {
        let a = if j == 0 { 0 } else { arg[j][b] };
        bounds.push((a, b));
        if j == 0 {
            break;
        }
        b = a - 1;
        j -= 1;
    }
    bounds.reverse();
    let sizes = bounds
        .iter()
        .map(|&(a, b)| prefix_sizes[b + 1] - prefix_sizes[a])
        .collect();
    let peaks = bounds.iter().map(|&(_, b)| run_peaks[b]).collect();
    Segmentation { sizes, peaks }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::run_prop;
    use crate::util::rng::Rng;

    #[test]
    fn envelope_is_running_max() {
        assert_eq!(
            monotone_envelope(&[1.0, 3.0, 2.0, 5.0, 4.0]),
            vec![1.0, 3.0, 3.0, 5.0, 5.0]
        );
    }

    #[test]
    fn bwa_like_two_segments() {
        // Fig 2: low plateau then high plateau -> two variable segments.
        let mut s = vec![5.0; 80];
        s.extend(vec![10.5; 20]);
        let seg = get_segments(&s, 2);
        assert_eq!(seg.peaks, vec![5.0, 10.5]);
        assert_eq!(seg.sizes, vec![80, 20]);
    }

    #[test]
    fn k_one_is_flat_peak() {
        let s = [1.0, 7.0, 3.0, 2.0];
        let seg = get_segments(&s, 1);
        assert_eq!(seg.peaks, vec![7.0]);
        assert_eq!(seg.sizes, vec![4]);
    }

    #[test]
    fn fewer_steps_than_k() {
        let s = [2.0, 2.0, 2.0];
        let seg = get_segments(&s, 5);
        assert_eq!(seg.peaks, vec![2.0]);
        assert_eq!(seg.sizes, vec![3]);
    }

    #[test]
    fn greedy_merges_smallest_error() {
        // Envelope steps: (1 sample @1), (1 @2), (1 @10).
        // e_0 = (2-1)*1 = 1, e_1 = (10-2)*1 = 8 -> merge 0 into 1 first.
        let s = [1.0, 2.0, 10.0];
        let seg = get_segments(&s, 2);
        assert_eq!(seg.peaks, vec![2.0, 10.0]);
        assert_eq!(seg.sizes, vec![2, 1]);
    }

    #[test]
    fn to_plan_time_domain() {
        let seg = Segmentation { sizes: vec![80, 20], peaks: vec![5.0, 10.5] };
        let plan = seg.to_plan(2.0);
        assert_eq!(plan.starts, vec![0.0, 160.0]);
        assert!(plan.is_valid());
        assert_eq!(plan.alloc_at(159.9), 5.0);
        assert_eq!(plan.alloc_at(160.0), 10.5);
    }

    #[test]
    fn start_offsets_cumulative() {
        let seg = Segmentation { sizes: vec![3, 4, 5], peaks: vec![1.0, 2.0, 3.0] };
        assert_eq!(seg.start_offsets(), vec![0, 3, 7]);
    }

    #[test]
    fn optimal_matches_greedy_on_plateaus() {
        let mut s = vec![5.0; 80];
        s.extend(vec![10.5; 20]);
        let g = get_segments(&s, 2);
        let o = optimal_segments(&s, 2);
        assert_eq!(g, o);
    }

    #[test]
    fn optimal_never_worse_than_greedy() {
        run_prop("dp_beats_greedy", 150, |rng| {
            let n = 10 + rng.below(120);
            let mut level = rng.uniform(0.5, 2.0);
            let mut s = Vec::with_capacity(n);
            for _ in 0..n {
                if rng.f64() < 0.15 {
                    level += rng.uniform(0.0, 3.0);
                }
                s.push(level * (1.0 - 0.05 * rng.f64()));
            }
            let k = 1 + rng.below(6);
            let g = get_segments(&s, k);
            let o = optimal_segments(&s, k);
            let ge = g.envelope_error(&s);
            let oe = o.envelope_error(&s);
            assert!(
                oe <= ge + 1e-9,
                "optimal {oe} worse than greedy {ge} (n={n}, k={k})"
            );
            assert!(o.peaks.len() <= k && g.peaks.len() <= k);
        });
    }

    #[test]
    fn prop_segmentation_invariants() {
        run_prop("segmentation_invariants", 200, |rng| {
            let n = 1 + rng.below(200);
            let s: Vec<f64> = (0..n).map(|_| rng.uniform(0.1, 16.0)).collect();
            let k = 1 + rng.below(8);
            let seg = get_segments(&s, k);
            // 1. at most k segments
            assert!(seg.peaks.len() <= k);
            // 2. sizes partition the series
            assert_eq!(seg.sizes.iter().sum::<usize>(), n);
            // 3. peaks strictly increasing (variable segments never repeat)
            for w in seg.peaks.windows(2) {
                assert!(w[0] < w[1], "peaks not increasing: {:?}", seg.peaks);
            }
            // 4. the plan covers every sample (allocation >= usage)
            let plan = seg.to_plan(1.0);
            for (i, &u) in s.iter().enumerate() {
                assert!(
                    plan.alloc_at(i as f64) >= u - 1e-12,
                    "sample {i} above allocation"
                );
            }
            // 5. last peak equals the global max
            let max = s.iter().cloned().fold(f64::MIN, f64::max);
            assert!((seg.peaks.last().unwrap() - max).abs() < 1e-12);
        });
    }

    #[test]
    fn prop_monotone_pass_is_envelope() {
        run_prop("pass1_envelope", 100, |rng| {
            let n = 1 + rng.below(100);
            let s: Vec<f64> = (0..n).map(|_| rng.uniform(0.0, 10.0)).collect();
            // With k = n no merging happens in step 2.
            let seg = get_segments(&s, n);
            // Reconstruct the step function and compare to the envelope.
            let env = monotone_envelope(&s);
            let mut idx = 0;
            for (seg_i, &size) in seg.sizes.iter().enumerate() {
                for _ in 0..size {
                    assert!(
                        seg.peaks[seg_i] >= env[idx] - 1e-12,
                        "segment peak below envelope"
                    );
                    idx += 1;
                }
            }
            // Peak of each segment equals envelope at the segment end.
            let mut acc = 0;
            for (seg_i, &size) in seg.sizes.iter().enumerate() {
                acc += size;
                assert!((seg.peaks[seg_i] - env[acc - 1]).abs() < 1e-12);
            }
        });
    }

    #[test]
    fn heap_matches_quadratic_on_fixtures() {
        // The exact cases the paper motivates: plateaus, staircases, and
        // tie-heavy series where merge order matters.
        let cases: Vec<(Vec<f64>, usize)> = vec![
            (vec![5.0; 80].into_iter().chain(vec![10.5; 20]).collect(), 2),
            (vec![1.0, 2.0, 10.0], 2),
            (vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], 3), // all merge errors equal
            (vec![2.0, 2.0, 2.0], 5),
            (vec![1.0, 7.0, 3.0, 2.0], 1),
            ((0..64).map(|i| (i % 7) as f64 + i as f64 * 0.1).collect(), 4),
        ];
        for (s, k) in cases {
            assert_eq!(
                get_segments(&s, k),
                get_segments_quadratic(&s, k),
                "diverged on k={k}, series {s:?}"
            );
        }
    }

    #[test]
    fn prop_heap_is_bit_identical_to_quadratic_oracle() {
        // Satellite: across random series, k, and plateau shapes, the
        // heap-based merge must reproduce the quadratic loop bit for bit
        // (same f64 peaks, same sizes) — identical merge sequences
        // including tie-breaks.
        run_prop("heap_vs_quadratic_oracle", 300, |rng| {
            let n = 1 + rng.below(400);
            let shape = rng.below(4);
            let mut level = rng.uniform(0.1, 4.0);
            let s: Vec<f64> = (0..n)
                .map(|_| match shape {
                    // Plateau staircase (integer levels force error ties).
                    0 => {
                        if rng.f64() < 0.15 {
                            level += 1.0;
                        }
                        level
                    }
                    // Noisy plateaus.
                    1 => {
                        if rng.f64() < 0.2 {
                            level += rng.uniform(0.0, 2.0);
                        }
                        level * (1.0 - 0.05 * rng.f64())
                    }
                    // Noisy ramp: many envelope steps.
                    2 => {
                        level += rng.uniform(0.0, 0.05);
                        level * (1.0 - 0.01 * rng.f64())
                    }
                    // White noise.
                    _ => rng.uniform(0.0, 16.0),
                })
                .collect();
            let k = 1 + rng.below(10);
            let heap = get_segments(&s, k);
            let quad = get_segments_quadratic(&s, k);
            assert_eq!(heap, quad, "diverged on n={n}, k={k}, shape={shape}");
        });
    }

    #[test]
    fn deterministic_rng_fixture() {
        // Pin one realistic case end-to-end.
        let mut rng = Rng::new(42);
        let s: Vec<f64> = (0..100)
            .map(|i| if i < 70 { 5.0 + 0.1 * rng.f64() } else { 10.0 + 0.2 * rng.f64() })
            .collect();
        let seg = get_segments(&s, 2);
        assert_eq!(seg.sizes.iter().sum::<usize>(), 100);
        assert_eq!(seg.peaks.len(), 2);
        assert!(seg.peaks[0] < 5.2 && seg.peaks[0] >= 5.0);
        assert!(seg.peaks[1] >= 10.0);
        // Boundary near sample 70.
        assert!((seg.sizes[0] as i64 - 70).unsigned_abs() <= 2);
    }
}
