//! Cross-module integration tests: the full pipeline from trace
//! generation through training, simulation, the PJRT runtime, and the
//! coordinator wire protocol.

use std::collections::BTreeMap;

use ksplus::coordinator::remote::RemoteClient;
use ksplus::coordinator::server::Server;
use ksplus::coordinator::service::{Coordinator, CoordinatorConfig};
use ksplus::coordinator::{Backend, BackendSpec, ModelStore, PredictorPolicy};
use ksplus::experiments::{evaluate_method, trained_predictor};
use ksplus::metrics::WastageReport;
use ksplus::predictor::{by_name, paper_methods, Predictor};
#[cfg(feature = "pjrt")]
use ksplus::runtime::{default_artifacts_dir, Runtime};
use ksplus::sim::cluster::{run_cluster, ClusterConfig, PredictorSource};
use ksplus::sim::run_all;
#[cfg(feature = "pjrt")]
use ksplus::sim::{run_task, MAX_RETRIES};
use ksplus::trace::workflow::Workflow;
use ksplus::trace::{io as trace_io, split_train_test};
use ksplus::util::rng::Rng;

#[cfg(feature = "pjrt")]
fn artifacts() -> Option<std::path::PathBuf> {
    let dir = default_artifacts_dir();
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts not built");
        None
    }
}

#[test]
fn full_pipeline_method_ordering() {
    // trace-gen -> split -> train -> simulate for every paper method;
    // the paper's ordering must hold on both workflows.
    for wf in [Workflow::eager(), Workflow::sarek()] {
        let trace = wf.generate(42, 150);
        let mut totals: BTreeMap<&str, f64> = BTreeMap::new();
        for method in paper_methods() {
            let r = evaluate_method(method, 4, 128.0, &wf, &trace, 0.5, 1).unwrap();
            totals.insert(method, r.total_wastage_gbs());
        }
        assert!(totals["ksplus"] < totals["ksegments-selective"], "{totals:?}");
        assert!(totals["ksegments-selective"] <= totals["ksegments-partial"], "{totals:?}");
        assert!(totals["ksplus"] < totals["ppm-improved"], "{totals:?}");
        assert!(totals["ppm-improved"] < totals["tovar-ppm"], "{totals:?}");
    }
}

#[test]
fn csv_roundtrip_feeds_training() {
    // Write a generated trace to CSV, read it back, train, and verify
    // the plans match plans trained on the in-memory trace.
    let wf = Workflow::eager();
    let trace = wf.generate(7, 100);
    let path = std::env::temp_dir().join(format!("ksplus_int_{}.csv", std::process::id()));
    trace_io::write_csv(&path, &trace).unwrap();
    let back = trace_io::read_csv(&path, "eager").unwrap();
    std::fs::remove_file(&path).ok();

    let bwa_mem = trace.task("bwa").unwrap();
    let bwa_csv = back.task("bwa").unwrap();
    let mut p_mem = by_name("ksplus", 3, 128.0).unwrap();
    p_mem.train(&bwa_mem.executions);
    let mut p_csv = by_name("ksplus", 3, 128.0).unwrap();
    p_csv.train(&bwa_csv.executions);
    let a = p_mem.plan(8000.0);
    let b = p_csv.plan(8000.0);
    assert_eq!(a.k(), b.k());
    for i in 0..a.k() {
        // CSV stores 4 decimals; tolerances accordingly.
        assert!((a.starts[i] - b.starts[i]).abs() < 1.0, "{a:?} vs {b:?}");
        assert!((a.peaks[i] - b.peaks[i]).abs() < 0.05, "{a:?} vs {b:?}");
    }
}

#[test]
fn every_method_finishes_every_task() {
    // No predictor may leave a feasible task unfinished after retries.
    let wf = Workflow::sarek();
    let trace = wf.generate(9, 120);
    for method in paper_methods() {
        for t in trace.tasks.iter().take(4) {
            let mut rng = Rng::new(3);
            let (train, test) = split_train_test(t, 0.5, &mut rng);
            let pred = trained_predictor(method, 4, 128.0, &wf, &t.task, &train).unwrap();
            for o in run_all(pred.as_ref(), &test[..test.len().min(10)]) {
                assert!(o.success, "{method}/{}: unfinished task", t.task);
                assert!(o.wastage_gbs.is_finite() && o.wastage_gbs >= 0.0);
            }
        }
    }
}

#[cfg(feature = "pjrt")]
#[test]
fn pjrt_plan_scoring_matches_simulator() {
    // The experiment metric computed host-side must equal the AOT
    // plan_wastage kernel's result for covering plans.
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::load(&dir).unwrap();
    let wf = Workflow::eager();
    let trace = wf.generate(11, 150);
    let bwa = trace.task("bwa").unwrap();
    let mut rng = Rng::new(5);
    let (train, test) = split_train_test(bwa, 0.5, &mut rng);
    let mut pred = by_name("ksplus", 4, 128.0).unwrap();
    pred.train(&train);

    let mut rows = Vec::new();
    let mut host = Vec::new();
    for e in &test {
        let (outcome, attempts) = run_task(pred.as_ref(), e, MAX_RETRIES);
        assert!(outcome.success);
        // Score only the successful attempt (failures are host-side
        // bookkeeping of a partial run).
        let plan = &attempts.last().unwrap().plan;
        rows.push((plan.clone(), e.samples.clone(), e.dt));
        host.push(plan.wastage_gbs(e));
    }
    let device = rt.plan_wastage_batch(&rows).unwrap();
    for (i, (d, h)) in device.iter().zip(&host).enumerate() {
        let tol = h.max(1.0) * 2e-3;
        assert!((d - h).abs() < tol, "row {i}: device {d} vs host {h}");
    }
}

#[cfg(feature = "pjrt")]
#[test]
fn wire_protocol_end_to_end_with_pjrt() {
    // TCP server -> coordinator -> PJRT artifacts -> plan -> simulate ->
    // failure report -> retry covers.
    let Some(dir) = artifacts() else { return };
    let coord = Coordinator::start(
        CoordinatorConfig { k: 4, ..Default::default() },
        BackendSpec::Pjrt(Some(dir)),
    )
    .unwrap();
    let server = Server::start("127.0.0.1:0", coord.client()).unwrap();

    use std::io::{BufRead, BufReader, Write};
    let mut stream = std::net::TcpStream::connect(server.addr()).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut roundtrip = |req: &str| -> ksplus::util::json::Json {
        writeln!(stream, "{req}").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        ksplus::util::json::Json::parse(&line).unwrap()
    };

    let wf = Workflow::eager();
    let trace = wf.generate(13, 120);
    let bwa = trace.task("bwa").unwrap();
    // Train over the wire.
    let hist_json: Vec<String> = bwa
        .executions
        .iter()
        .take(30)
        .map(|e| {
            let samples: Vec<String> = e.samples.iter().map(|s| format!("{s:.4}")).collect();
            format!(
                r#"{{"input_mb":{:.2},"dt":{:.3},"samples":[{}]}}"#,
                e.input_mb,
                e.dt,
                samples.join(",")
            )
        })
        .collect();
    let r = roundtrip(&format!(
        r#"{{"op":"train","task":"bwa","history":[{}]}}"#,
        hist_json.join(",")
    ));
    assert_eq!(r.get("ok").and_then(|j| j.as_bool()), Some(true), "{r}");

    // Plan for a held-out execution; simulate; report failures until done.
    let e = &bwa.executions[35];
    let r = roundtrip(&format!(
        r#"{{"op":"plan","task":"bwa","input_mb":{:.2}}}"#,
        e.input_mb
    ));
    assert_eq!(r.get("ok").and_then(|j| j.as_bool()), Some(true), "{r}");
    let to_plan = |j: &ksplus::util::json::Json| -> ksplus::segments::StepPlan {
        let v = |k: &str| -> Vec<f64> {
            j.get(k).unwrap().as_arr().unwrap().iter().map(|x| x.as_f64().unwrap()).collect()
        };
        ksplus::segments::StepPlan::new(v("starts"), v("peaks"))
    };
    let mut plan = to_plan(r.get("plan").unwrap());
    assert!(plan.is_valid());
    for _ in 0..10 {
        match plan.first_oom(e) {
            None => break,
            Some((t, _)) => {
                let r = roundtrip(&format!(
                    r#"{{"op":"failure","plan":{{"starts":{},"peaks":{}}},"fail_time":{t}}}"#,
                    ksplus::util::json::Json::arr_f64(&plan.starts),
                    ksplus::util::json::Json::arr_f64(&plan.peaks),
                ));
                assert_eq!(r.get("ok").and_then(|j| j.as_bool()), Some(true), "{r}");
                plan = to_plan(r.get("plan").unwrap());
            }
        }
    }
    assert!(plan.covers(e), "retry loop over the wire never converged");
}

#[test]
fn per_task_policies_over_tcp_with_provenance_and_ksplus_parity() {
    // The acceptance scenario: two tasks with different policies on ONE
    // running server, train/observe/plan driven over TCP through the
    // typed client, per-plan provenance checked, and the KS+ plan
    // bit-identical to a seed-equivalent ModelStore fed the same data
    // in-process (the pre-redesign path).
    let (_coord, server) = Server::start_with_backend(
        "127.0.0.1:0",
        CoordinatorConfig { k: 3, shards: 2, ..Default::default() },
        BackendSpec::Native,
    )
    .unwrap();
    let mut rc = RemoteClient::connect(server.addr()).unwrap();
    assert_eq!(rc.hello().unwrap().version, 1);
    rc.configure(Some("bwa"), PredictorPolicy::KsPlus).unwrap();
    rc.configure(Some("idx"), PredictorPolicy::WittLr).unwrap();

    let wf = Workflow::eager();
    let trace = wf.generate(77, 60);
    let hist = &trace.task("bwa").unwrap().executions;
    let (batch, streamed) = hist.split_at(hist.len() - 5);

    // Train + observe over the wire...
    assert_eq!(rc.train("bwa", batch).unwrap(), batch.len() as u64);
    for (i, e) in streamed.iter().enumerate() {
        let ack = rc.observe("bwa", e).unwrap();
        assert_eq!(ack.executions, (batch.len() + i + 1) as u64);
        assert_eq!(ack.predictor, "ksplus");
    }
    rc.train("idx", batch).unwrap();

    // ...and replicate the identical sequence on an in-process store.
    let mut store = ModelStore::new(3, 128.0, Backend::Native);
    store.train("bwa", batch);
    for e in streamed {
        store.observe("bwa", e);
    }

    for input in [2500.0, 6000.0, 11000.0] {
        let got = rc.plan("bwa", input).unwrap();
        assert_eq!(got.predictor, "ksplus", "input {input}");
        assert_eq!(got.model_version, hist.len() as u64);
        assert_eq!(got.fallback_reason, None);
        let want = store.plan_batch(&[("bwa", input)]);
        // Bit-identical across training, planning, AND the JSON wire
        // (shortest-roundtrip float formatting).
        assert_eq!(got.plan.starts, want[0].starts, "input {input}");
        assert_eq!(got.plan.peaks, want[0].peaks, "input {input}");
    }

    // The witt-bound task serves flat witt plans with its provenance.
    let wt = rc.plan("idx", 6000.0).unwrap();
    assert_eq!(wt.predictor, "witt-lr");
    assert_eq!(wt.model_version, batch.len() as u64);
    assert_eq!(wt.plan.k(), 1);
    {
        use ksplus::predictor::witt::{Offset, WittLr};
        use ksplus::predictor::Predictor;
        let mut want = WittLr::new(128.0, Offset::MeanSigma);
        want.train(batch);
        assert_eq!(wt.plan, want.plan(6000.0));
    }

    // An untrained task is a visible fallback, and counted.
    let fb = rc.plan("mystery", 100.0).unwrap();
    assert_eq!(fb.predictor, "default-limits");
    assert_eq!(fb.fallback_reason, Some("untrained-task"));
    let s = rc.stats().unwrap();
    assert_eq!(s.fallbacks, 1);
    assert_eq!(s.requests, 5);
    assert_eq!(s.observations, 5);
}

#[test]
fn observe_stream_equals_batch_train_on_real_workflow() {
    // Incremental training end-to-end on a real trace: a coordinator fed
    // one `observe` per execution must serve plans bit-identical to a
    // coordinator batch-trained on the same history — for every task
    // type, across whichever shards the names hash to.
    let wf = Workflow::eager();
    let trace = wf.generate(31, 80);
    let cfg = |shards| CoordinatorConfig { k: 3, shards, ..Default::default() };
    let batch = Coordinator::start(cfg(2), BackendSpec::Native).unwrap();
    let streamed = Coordinator::start(cfg(2), BackendSpec::Native).unwrap();
    for t in &trace.tasks {
        batch.client().train(&t.task, t.executions.clone());
        for (i, e) in t.executions.iter().enumerate() {
            let n = streamed.client().observe(&t.task, e.clone());
            assert_eq!(n, i as u64 + 1, "task {}", t.task);
        }
    }
    for t in &trace.tasks {
        for input in [t.executions[0].input_mb, t.executions[1].input_mb * 1.7] {
            let a = batch.client().plan(&t.task, input);
            let b = streamed.client().plan(&t.task, input);
            assert_eq!(a.starts, b.starts, "task {} input {input}", t.task);
            assert_eq!(a.peaks, b.peaks, "task {} input {input}", t.task);
        }
    }
    let stats = streamed.client().stats();
    assert_eq!(stats.observations, trace.total_instances() as u64);
    assert_eq!(stats.tasks_trained, 0);
}

#[test]
fn sharded_coordinator_matches_single_shard_plans() {
    // Sharding is a pure scaling change: given identical training data,
    // the sharded pool must emit bit-identical plans to a single worker,
    // for every task of a real workflow (each task exercises whichever
    // shard its name hashes to).
    let wf = Workflow::eager();
    let trace = wf.generate(21, 100);
    let start = |shards: usize| {
        let coord = Coordinator::start(
            CoordinatorConfig { k: 3, shards, ..Default::default() },
            BackendSpec::Native,
        )
        .unwrap();
        let client = coord.client();
        for t in &trace.tasks {
            client.train(&t.task, t.executions.clone());
        }
        coord
    };
    let single = start(1);
    let sharded = start(4);
    for t in &trace.tasks {
        for input in [t.executions[0].input_mb, t.executions[1].input_mb * 1.5] {
            let a = single.client().plan(&t.task, input);
            let b = sharded.client().plan(&t.task, input);
            assert_eq!(a.starts, b.starts, "task {} input {input}", t.task);
            assert_eq!(a.peaks, b.peaks, "task {} input {input}", t.task);
        }
    }
    // The sharded pool actually used more than one worker for this mix.
    let per = sharded.client().shard_stats();
    assert!(per.iter().filter(|s| s.requests > 0).count() > 1, "{per:?}");
}

#[test]
fn cluster_simulation_all_methods_complete() {
    let wf = Workflow::eager();
    let trace = wf.generate(17, 100);
    struct Trained(BTreeMap<String, Box<dyn Predictor>>);
    impl PredictorSource for Trained {
        fn get(&self, task: &str) -> Option<&dyn Predictor> {
            self.0.get(task).map(|p| p.as_ref())
        }
    }
    for method in ["ksplus", "ppm-improved"] {
        let mut preds = Trained(BTreeMap::new());
        let mut test = Vec::new();
        for (idx, t) in trace.tasks.iter().enumerate() {
            let mut rng = Rng::new(1).fork(idx as u64);
            let (train_set, test_set) = split_train_test(t, 0.5, &mut rng);
            preds
                .0
                .insert(t.task.clone(), trained_predictor(method, 4, 128.0, &wf, &t.task, &train_set).unwrap());
            test.extend(test_set.into_iter().take(5));
        }
        let r = run_cluster(&ClusterConfig { nodes: 2, node_capacity_gb: 128.0 }, &preds, &test);
        assert_eq!(r.outcomes.len(), test.len(), "{method}");
        assert!(r.outcomes.iter().all(|o| o.success), "{method}");
        assert!(r.makespan_s > 0.0);
        // Reservations never exceeded capacity.
        assert!(r.peak_reserved_gb.iter().all(|&p| p <= 128.0 + 1e-6));
    }
}

#[test]
fn auto_k_competitive_in_harness() {
    // ksplus-auto should be within 1.4x of fixed-k ksplus on eager
    // (selection noise allowed) and strictly better than ppm-improved.
    let wf = Workflow::eager();
    let trace = wf.generate(42, 150);
    let auto = evaluate_method("ksplus-auto", 4, 128.0, &wf, &trace, 0.5, 2).unwrap();
    let fixed = evaluate_method("ksplus", 4, 128.0, &wf, &trace, 0.5, 2).unwrap();
    let ppm = evaluate_method("ppm-improved", 4, 128.0, &wf, &trace, 0.5, 2).unwrap();
    let (a, f, p) =
        (auto.total_wastage_gbs(), fixed.total_wastage_gbs(), ppm.total_wastage_gbs());
    assert!(a < f * 1.4, "auto {a:.0} vs fixed {f:.0}");
    assert!(a < p, "auto {a:.0} vs ppm {p:.0}");
}

#[test]
fn report_aggregation_is_consistent() {
    // WastageReport totals equal the sum over tasks for a real run.
    let wf = Workflow::sarek();
    let trace = wf.generate(23, 100);
    let r = evaluate_method("ksplus", 4, 128.0, &wf, &trace, 0.25, 1).unwrap();
    let sum: f64 = trace
        .tasks
        .iter()
        .map(|t| r.task_wastage(&t.task))
        .sum();
    assert!((sum - r.total_wastage_gbs()).abs() < 1e-6);
    let rebuilt = WastageReport::from_outcomes(&[]);
    assert_eq!(rebuilt.total_instances(), 0);
}
