"""L2 model shape checks + AOT lowering smoke tests.

Verifies that every model entry point produces the manifest shapes and
that the HLO-text lowering used by aot.py succeeds for the shipped
buckets (the same path `make artifacts` runs).
"""

from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import aot, model
from compile.kernels import ols, ref


def test_fit_model_shapes():
    b, n = 128, 32
    x = jnp.zeros((b, n), jnp.float32)
    (coef,) = model.fit_model(x, x, x)
    assert coef.shape == (b, 2) and coef.dtype == jnp.float32


def test_predict_model_shapes():
    b = 128
    coef = jnp.zeros((b, 2), jnp.float32)
    v = jnp.zeros((b,), jnp.float32)
    (yhat,) = model.predict_model(coef, v, v)
    assert yhat.shape == (b,) and yhat.dtype == jnp.float32


def test_fit_predict_fused_equals_two_step():
    b, n = 128, 16
    rng = np.random.default_rng(3)
    x = rng.uniform(1, 100, size=(b, n)).astype(np.float32)
    y = (2.0 * x + 5.0).astype(np.float32)
    m = np.ones((b, n), np.float32)
    xq = rng.uniform(1, 100, size=b).astype(np.float32)
    scale = np.full(b, 1.1, np.float32)
    yhat, coef = model.fit_predict_model(x, y, m, xq, scale)
    (coef2,) = model.fit_model(x, y, m)
    (yhat2,) = model.predict_model(coef2, xq, scale)
    np.testing.assert_allclose(np.asarray(yhat), np.asarray(yhat2), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(coef), np.asarray(coef2), rtol=1e-6)


def test_wastage_model_shapes():
    b, n = 128, 64
    a = jnp.zeros((b, n), jnp.float32)
    dt = jnp.zeros((b,), jnp.float32)
    (w,) = model.wastage_model(a, a, a, dt)
    assert w.shape == (b,)


@pytest.mark.parametrize(
    "fn,specs",
    [
        (model.fit_model, [(128, 16)] * 3),
        (model.predict_model, [(128, 2), (128,), (128,)]),
        (model.fit_predict_model, [(128, 16)] * 3 + [(128,), (128,)]),
        (model.wastage_model, [(128, 16)] * 3 + [(128,)]),
    ],
)
def test_hlo_text_lowering(fn, specs):
    """Every entry point lowers to parseable non-empty HLO text."""
    shaped = [jax.ShapeDtypeStruct(s, jnp.float32) for s in specs]
    lowered = jax.jit(fn).lower(*shaped)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert len(text) > 200


def test_jit_fit_matches_eager():
    """jit-compiled path == eager path (what the artifact will compute)."""
    b, n = 128, 8
    rng = np.random.default_rng(11)
    x = rng.uniform(0, 10, size=(b, n)).astype(np.float32)
    y = rng.uniform(0, 10, size=(b, n)).astype(np.float32)
    m = np.ones((b, n), np.float32)
    (eager,) = model.fit_model(x, y, m)
    (jitted,) = jax.jit(model.fit_model)(x, y, m)
    np.testing.assert_allclose(np.asarray(eager), np.asarray(jitted), rtol=1e-5)
    np.testing.assert_allclose(
        np.asarray(eager), np.asarray(ref.fit_ref(x, y, m)), rtol=1e-4, atol=1e-4
    )
