//! KS+ with per-task dynamic segment-count selection — the paper's
//! stated future work ("we plan to dynamically determine the optimal
//! number of segments for each task").
//!
//! Selection is leave-some-out cross-validation on the training set: for
//! each candidate k, train KS+ on a subset and replay the held-out
//! executions through the OOM/retry loop (the same cost the evaluation
//! metric charges), then pick the k with the lowest CV wastage. Ties go
//! to the smaller k (fewer boundaries = fewer timing failure modes).

use crate::predictor::ksplus::KsPlus;
use crate::predictor::Predictor;
use crate::segments::StepPlan;
use crate::trace::Execution;
use crate::util::rng::Rng;

/// Candidate segment counts, bounded to keep training cheap.
pub const DEFAULT_CANDIDATES: &[usize] = &[1, 2, 3, 4, 6, 8];
/// CV folds.
const FOLDS: usize = 3;

pub struct KsPlusAuto {
    capacity: f64,
    candidates: Vec<usize>,
    inner: KsPlus,
    chosen_k: usize,
    /// CV wastage per candidate, for inspection/ablation.
    pub cv_wastage: Vec<(usize, f64)>,
}

impl KsPlusAuto {
    pub fn new(capacity: f64) -> Self {
        Self::with_candidates(capacity, DEFAULT_CANDIDATES.to_vec())
    }

    pub fn with_candidates(capacity: f64, candidates: Vec<usize>) -> Self {
        assert!(!candidates.is_empty());
        let k0 = candidates[0];
        KsPlusAuto {
            capacity,
            candidates,
            inner: KsPlus::new(k0, capacity),
            chosen_k: k0,
            cv_wastage: Vec::new(),
        }
    }

    pub fn chosen_k(&self) -> usize {
        self.chosen_k
    }

    /// CV wastage of candidate k on `history`.
    fn cv_cost(&self, k: usize, history: &[Execution]) -> f64 {
        let n = history.len();
        if n < 4 {
            // Too little data for CV; prefer the smallest k.
            return f64::INFINITY;
        }
        // Deterministic fold assignment (seeded by k-independent hash of
        // n so every candidate sees identical folds).
        let mut idx: Vec<usize> = (0..n).collect();
        Rng::new(0xC5EED ^ n as u64).shuffle(&mut idx);
        let mut total = 0.0;
        for fold in 0..FOLDS {
            let test_idx: Vec<usize> =
                idx.iter().copied().filter(|i| i % FOLDS == fold).collect();
            let train_set: Vec<Execution> = idx
                .iter()
                .filter(|i| *i % FOLDS != fold)
                .map(|&i| history[i].clone())
                .collect();
            if train_set.is_empty() || test_idx.is_empty() {
                continue;
            }
            let mut p = KsPlus::new(k, self.capacity);
            p.train(&train_set);
            for &i in &test_idx {
                let (o, _) = crate::sim::run_task(&p, &history[i], 6);
                total += o.wastage_gbs;
            }
        }
        total
    }
}

impl Predictor for KsPlusAuto {
    fn name(&self) -> &'static str {
        "ksplus-auto"
    }

    fn train(&mut self, history: &[Execution]) {
        self.cv_wastage.clear();
        let mut best = (self.candidates[0], f64::INFINITY);
        for &k in &self.candidates {
            let cost = self.cv_cost(k, history);
            self.cv_wastage.push((k, cost));
            // Strictly-better keeps the smaller k on ties.
            if cost < best.1 {
                best = (k, cost);
            }
        }
        // All-infinite (tiny history): fall back to a small fixed k.
        self.chosen_k = if best.1.is_finite() { best.0 } else { 2 };
        self.inner = KsPlus::new(self.chosen_k, self.capacity);
        self.inner.train(history);
    }

    fn plan(&self, input_mb: f64) -> StepPlan {
        self.inner.plan(input_mb)
    }

    fn on_failure(&self, prev: &StepPlan, fail_time: f64, attempt: usize) -> StepPlan {
        self.inner.on_failure(prev, fail_time, attempt)
    }

    fn capacity(&self) -> f64 {
        self.capacity
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::synth::eager_archetypes;

    fn two_phase_exec(input: f64, rng: &mut Rng) -> Execution {
        let d1 = ((input * 0.01) as usize).max(2);
        let d2 = ((input * 0.003) as usize).max(1);
        let mut s = vec![input * 0.0005; d1];
        s.extend(vec![input * 0.001; d2]);
        for v in s.iter_mut() {
            *v *= 1.0 - 0.01 * rng.f64();
        }
        Execution::new("t", input, 1.0, s)
    }

    #[test]
    fn selects_small_k_for_two_phase_task() {
        let mut rng = Rng::new(1);
        let hist: Vec<Execution> =
            (0..30).map(|_| two_phase_exec(rng.uniform(2000.0, 12000.0), &mut rng)).collect();
        let mut p = KsPlusAuto::new(128.0);
        p.train(&hist);
        // A clean two-plateau profile needs no more than ~4 segments.
        assert!(
            (2..=4).contains(&p.chosen_k()),
            "chose k={} for a two-phase task",
            p.chosen_k()
        );
        assert!(p.plan(5000.0).is_valid());
    }

    #[test]
    fn flat_task_selects_k1_or_2() {
        let mut rng = Rng::new(2);
        let hist: Vec<Execution> = (0..24)
            .map(|_| {
                let input = rng.uniform(500.0, 2000.0);
                let n = ((input * 0.02) as usize).max(3);
                Execution::new("t", input, 1.0, vec![input * 0.001; n])
            })
            .collect();
        let mut p = KsPlusAuto::new(128.0);
        p.train(&hist);
        assert!(p.chosen_k() <= 2, "flat task chose k={}", p.chosen_k());
    }

    #[test]
    fn tiny_history_falls_back() {
        let mut rng = Rng::new(3);
        let hist = vec![two_phase_exec(3000.0, &mut rng)];
        let mut p = KsPlusAuto::new(128.0);
        p.train(&hist);
        assert!(p.plan(3000.0).is_valid());
        assert_eq!(p.chosen_k(), 2);
    }

    #[test]
    fn cv_wastage_recorded_per_candidate() {
        let mut rng = Rng::new(4);
        let hist: Vec<Execution> =
            (0..20).map(|_| two_phase_exec(rng.uniform(2000.0, 9000.0), &mut rng)).collect();
        let mut p = KsPlusAuto::new(128.0);
        p.train(&hist);
        assert_eq!(p.cv_wastage.len(), DEFAULT_CANDIDATES.len());
        assert!(p.cv_wastage.iter().all(|(_, c)| c.is_finite()));
    }

    #[test]
    fn auto_not_worse_than_bad_fixed_k_on_bwa() {
        // On the bwa archetype, auto-k should beat a deliberately poor
        // fixed choice (k=10: many boundaries, many timing failures).
        let a = eager_archetypes().into_iter().find(|a| a.name == "bwa").unwrap();
        let mut rng = Rng::new(5);
        let hist: Vec<Execution> = (0..40).map(|_| a.generate(&mut rng, 200)).collect();
        let test: Vec<Execution> = (0..25).map(|_| a.generate(&mut rng, 200)).collect();
        let mut auto = KsPlusAuto::new(128.0);
        auto.train(&hist);
        let mut fixed = KsPlus::new(10, 128.0);
        fixed.train(&hist);
        let w = |p: &dyn Predictor| -> f64 {
            test.iter().map(|e| crate::sim::run_task(p, e, 10).0.wastage_gbs).sum()
        };
        let wa = w(&auto);
        let wf = w(&fixed);
        assert!(wa <= wf * 1.15, "auto {wa:.0} much worse than fixed-10 {wf:.0}");
    }

    #[test]
    fn retry_delegates_to_inner() {
        let p = KsPlusAuto::new(128.0);
        let prev = StepPlan::new(vec![0.0, 100.0], vec![2.0, 8.0]);
        let retry = p.on_failure(&prev, 60.0, 1);
        assert_eq!(retry.starts, vec![0.0, 60.0]);
    }
}
