//! `RemoteClient`: typed TCP client for the coordinator wire — the
//! counterpart of the in-process `service::Client`, sharing the exact
//! `Request`/`Response` types of `coordinator::protocol` with the
//! server, so client and server cannot drift.
//!
//! Every connection starts on wire v1 (newline-delimited JSON).
//! [`RemoteClient::negotiate`] offers the server a higher version; when
//! the server grants wire v2, the connection switches to the
//! length-prefixed binary framing of `coordinator::wire` for everything
//! after the hello response. Either way the typed surface is identical
//! — the codec is connection state, not API.
//!
//! One request/response pair per call, or [`RemoteClient::pipeline`]
//! to ship a batch of requests in one write and collect their responses
//! in order. Server-side errors surface as the structured `WireError`
//! (`code: message` via its `Display`) wrapped in `anyhow::Error`.
//!
//! ```no_run
//! # use ksplus::coordinator::remote::RemoteClient;
//! # use ksplus::coordinator::PredictorPolicy;
//! # fn main() -> anyhow::Result<()> {
//! let mut rc = RemoteClient::connect("127.0.0.1:7070")?;
//! let info = rc.negotiate(2)?; // binary wire when the server has it
//! rc.configure(Some("bwa"), PredictorPolicy::WittLr)?;
//! let out = rc.plan("bwa", 8000.0)?;
//! println!("served by {} (v{})", out.predictor, out.model_version);
//! # Ok(())
//! # }
//! ```

use std::io::{BufReader, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::coordinator::protocol::{
    ObserveAck, Request, Response, ServerInfo, StatsSummary, WireError, WIRE_VERSION,
};
use crate::coordinator::wire::{
    decode_response, read_frame, try_encode_request, FrameRead, Wire, DEFAULT_MAX_FRAME_BYTES,
};
use crate::coordinator::{PlanOutcome, PredictorPolicy, RetryOutcome};
use crate::segments::StepPlan;
use crate::trace::Execution;
use crate::util::json::Json;

/// Client-side cap on one response frame. Far above the server's
/// request cap because a `snapshot` response carries the whole model
/// store inline.
pub const CLIENT_MAX_FRAME_BYTES: usize = 1 << 26;

pub struct RemoteClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    wire: Wire,
    /// Outbound request cap, mirroring the server's `--max-frame-bytes`.
    /// An over-cap request is refused *before* any byte is written — the
    /// server would answer `request-too-large` and close; refusing
    /// client-side keeps the connection usable.
    max_request_bytes: usize,
}

impl RemoteClient {
    pub fn connect<A: ToSocketAddrs>(addr: A) -> Result<RemoteClient> {
        let stream = TcpStream::connect(addr).context("connect to coordinator")?;
        RemoteClient::from_stream(stream)
    }

    /// Like [`connect`](RemoteClient::connect), but bounds the TCP
    /// connect and every subsequent read *and* write by `timeout` — a
    /// hung or unreachable coordinator fails the call instead of
    /// blocking the workflow engine forever. (Writes block too once the
    /// socket's send buffer fills against a stalled peer; bounding only
    /// reads was a hole.)
    pub fn connect_with_timeout<A: ToSocketAddrs>(
        addr: A,
        timeout: Duration,
    ) -> Result<RemoteClient> {
        let resolved = addr
            .to_socket_addrs()
            .context("resolve coordinator address")?
            .next()
            .ok_or_else(|| anyhow::anyhow!("coordinator address resolved to nothing"))?;
        let stream = TcpStream::connect_timeout(&resolved, timeout)
            .with_context(|| format!("connect to coordinator at {resolved}"))?;
        let mut rc = RemoteClient::from_stream(stream)?;
        rc.set_read_timeout(Some(timeout))?;
        rc.set_write_timeout(Some(timeout))?;
        Ok(rc)
    }

    fn from_stream(stream: TcpStream) -> Result<RemoteClient> {
        stream.set_nodelay(true).ok();
        let writer = stream.try_clone().context("clone coordinator stream")?;
        Ok(RemoteClient {
            reader: BufReader::new(stream),
            writer,
            wire: Wire::V1,
            max_request_bytes: DEFAULT_MAX_FRAME_BYTES,
        })
    }

    /// Set the outbound request cap (use the value the server was given
    /// with `--max-frame-bytes`). Requests that encode over the cap come
    /// back as a structured `request-too-large` without touching the
    /// wire, so the connection survives.
    pub fn set_max_request_bytes(&mut self, max: usize) {
        self.max_request_bytes = max;
    }

    /// The wire this connection currently speaks.
    pub fn wire(&self) -> Wire {
        self.wire
    }

    /// Bound every response read. A read that times out leaves the
    /// connection mid-frame — treat the client as dead and reconnect.
    pub fn set_read_timeout(&mut self, timeout: Option<Duration>) -> Result<()> {
        self.reader.get_ref().set_read_timeout(timeout).context("set read timeout")
    }

    /// Bound every request write (a stalled server eventually fills the
    /// socket's send buffer; an unbounded write then blocks forever).
    /// Same caveat as reads: a timed-out write leaves the connection
    /// mid-frame.
    pub fn set_write_timeout(&mut self, timeout: Option<Duration>) -> Result<()> {
        self.writer.set_write_timeout(timeout).context("set write timeout")
    }

    /// Send one raw v1 line and parse the reply as JSON. Escape hatch
    /// for conformance tests that need to ship intentionally malformed
    /// requests; typed callers use the op methods. Only meaningful on a
    /// wire-v1 connection — after a v2 upgrade raw line bytes would
    /// corrupt the binary framing, so this refuses.
    pub fn raw(&mut self, line: &str) -> Result<Json> {
        anyhow::ensure!(
            self.wire == Wire::V1,
            "raw lines are a wire-v1 escape hatch; this connection negotiated {}",
            self.wire.name()
        );
        writeln!(self.writer, "{line}").context("write request")?;
        match read_frame(&mut self.reader, Wire::V1, CLIENT_MAX_FRAME_BYTES)
            .context("read response")?
        {
            FrameRead::Frame(payload) => {
                let text = String::from_utf8_lossy(&payload);
                Json::parse(&text).map_err(|e| anyhow::anyhow!("unparseable response: {e}"))
            }
            FrameRead::Eof => anyhow::bail!("server closed the connection"),
            FrameRead::TooLong => anyhow::bail!("response exceeded the client frame cap"),
            FrameRead::TimedOut => anyhow::bail!("response read timed out"),
        }
    }

    /// Read one framed response off the connection and decode it for
    /// `op`, separating transport failures (`Err`) from structured
    /// server-side errors (`Ok(Err(_))`).
    fn read_response(&mut self, op: &str) -> Result<Result<Response, WireError>> {
        match read_frame(&mut self.reader, self.wire, CLIENT_MAX_FRAME_BYTES)
            .context("read response")?
        {
            FrameRead::Frame(payload) => match decode_response(self.wire, &payload, op) {
                Ok(resp) => Ok(Ok(resp)),
                Err(e) => Ok(Err(e)),
            },
            FrameRead::Eof => anyhow::bail!("server closed the connection"),
            FrameRead::TooLong => anyhow::bail!("response exceeded the client frame cap"),
            FrameRead::TimedOut => anyhow::bail!("response read timed out"),
        }
    }

    /// Send one typed request and return the server's verdict with the
    /// structured error preserved: `Err` is a transport/decoding
    /// failure, `Ok(Err(WireError))` a well-formed server-side
    /// rejection. The parity suite uses this to compare error codes and
    /// messages across wires; ordinary callers use the op methods.
    pub fn call_raw(&mut self, req: &Request) -> Result<Result<Response, WireError>> {
        let bytes = match try_encode_request(self.wire, req, self.max_request_bytes) {
            Ok(b) => b,
            // Nothing was written, so the stream is still in sync; the
            // refusal is the same structured error the server would send
            // (followed by a close, which this path avoids).
            Err(e) => return Ok(Err(e)),
        };
        self.writer.write_all(&bytes).context("write request")?;
        self.read_response(req.op())
    }

    fn call(&mut self, req: &Request) -> Result<Response> {
        self.call_raw(req)?.map_err(report_wire_error)
    }

    /// Ship every request in one write, then collect their responses in
    /// order — request pipelining. Each slot is that request's verdict
    /// (`Err(WireError)` for structured rejections); a transport
    /// failure aborts the whole batch. `hello` must not ride a pipeline
    /// (its response can switch the codec mid-stream); negotiate first.
    pub fn pipeline(&mut self, reqs: &[Request]) -> Result<Vec<Result<Response, WireError>>> {
        anyhow::ensure!(
            !reqs.iter().any(|r| matches!(r, Request::Hello { .. })),
            "hello cannot be pipelined; use negotiate() before the batch"
        );
        // Encode the whole batch before writing anything: if one request
        // is over the cap, the batch is refused with nothing on the wire
        // (a partial pipeline would desynchronize request/response
        // pairing).
        let mut batch = Vec::new();
        for req in reqs {
            let bytes = try_encode_request(self.wire, req, self.max_request_bytes)
                .map_err(|e| anyhow::anyhow!("pipelined {} request: {e}", req.op()))?;
            batch.extend_from_slice(&bytes);
        }
        self.writer.write_all(&batch).context("write pipelined batch")?;
        reqs.iter().map(|req| self.read_response(req.op())).collect()
    }

    /// Version/capability negotiation. Offers the server versions
    /// `1..=max_version`; the connection switches to whatever the
    /// server grants (the hello response itself still arrives on the
    /// wire the hello was sent on). Negotiation is conservative: a
    /// server that predates wire v2 — or this one, when `max_version`
    /// is 1 — leaves the connection on v1.
    pub fn negotiate(&mut self, max_version: usize) -> Result<ServerInfo> {
        match self.call(&Request::Hello {
            client: Some("ksplus-remote-client".into()),
            min_version: Some(WIRE_VERSION),
            max_version: Some(max_version),
        })? {
            Response::Hello(info) => {
                if let Some(w) = Wire::from_version(info.version) {
                    self.wire = w;
                }
                Ok(info)
            }
            other => anyhow::bail!("unexpected response to hello: {other:?}"),
        }
    }

    /// Version/capability negotiation pinned to wire v1. Call once
    /// after connecting; fails if the server cannot speak wire v1.
    pub fn hello(&mut self) -> Result<ServerInfo> {
        self.negotiate(WIRE_VERSION)
    }

    /// Bind a task (or, with `None`, the service-wide default) to a
    /// predictor policy.
    pub fn configure(&mut self, task: Option<&str>, policy: PredictorPolicy) -> Result<()> {
        match self.call(&Request::Configure { task: task.map(str::to_string), policy })? {
            Response::Configured { .. } => Ok(()),
            other => anyhow::bail!("unexpected response to configure: {other:?}"),
        }
    }

    /// Batch-train the task; returns the number of executions shipped.
    pub fn train(&mut self, task: &str, history: &[Execution]) -> Result<u64> {
        match self.call(&Request::Train { task: task.to_string(), history: history.to_vec() })? {
            Response::Trained { executions, .. } => Ok(executions),
            other => anyhow::bail!("unexpected response to train: {other:?}"),
        }
    }

    /// Fold one finished execution into the task's models.
    pub fn observe(&mut self, task: &str, execution: &Execution) -> Result<ObserveAck> {
        match self.call(&Request::Observe {
            task: task.to_string(),
            execution: execution.clone(),
        })? {
            Response::Observed(ack) => Ok(ack),
            other => anyhow::bail!("unexpected response to observe: {other:?}"),
        }
    }

    /// Request an allocation plan; the outcome carries provenance.
    pub fn plan(&mut self, task: &str, input_mb: f64) -> Result<PlanOutcome> {
        match self.call(&Request::Plan { task: task.to_string(), input_mb })? {
            Response::Planned(out) => Ok(out),
            other => anyhow::bail!("unexpected response to plan: {other:?}"),
        }
    }

    /// Report an OOM. With `task`, the retry uses that task's bound
    /// policy; without, the KS+ segment-rescaling strategy.
    pub fn report_failure(
        &mut self,
        task: Option<&str>,
        plan: &StepPlan,
        fail_time: f64,
    ) -> Result<RetryOutcome> {
        match self.call(&Request::Failure {
            task: task.map(str::to_string),
            plan: plan.clone(),
            fail_time,
        })? {
            Response::Retry(r) => Ok(r),
            other => anyhow::bail!("unexpected response to failure: {other:?}"),
        }
    }

    /// Merged service counters across every shard.
    pub fn stats(&mut self) -> Result<StatsSummary> {
        match self.call(&Request::Stats)? {
            Response::Stats(s) => Ok(s),
            other => anyhow::bail!("unexpected response to stats: {other:?}"),
        }
    }

    /// Dump the server's full model state as a restorable snapshot
    /// document (admin op; check `hello().ops` for `"snapshot"`).
    pub fn snapshot(&mut self) -> Result<Json> {
        match self.call(&Request::Snapshot)? {
            Response::Snapshot { doc } => Ok(doc),
            other => anyhow::bail!("unexpected response to snapshot: {other:?}"),
        }
    }

    /// Resize the server's worker pool to `shards` workers; returns the
    /// live shard ids after the resize (admin op; check `hello().ops`
    /// for `"reshard"`).
    pub fn reshard(&mut self, shards: usize) -> Result<Vec<usize>> {
        match self.call(&Request::Reshard { shards })? {
            Response::Resharded { shard_ids } => Ok(shard_ids),
            other => anyhow::bail!("unexpected response to reshard: {other:?}"),
        }
    }
}

fn report_wire_error(e: WireError) -> anyhow::Error {
    // The blanket std-error conversion keeps "{code}: {message}".
    anyhow::Error::from(e)
}
