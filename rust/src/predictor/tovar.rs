//! Tovar et al. peak-probability baseline (Tovar-PPM) and the paper's
//! PPM-Improved variant.
//!
//! Tovar et al. [26] size tasks by choosing the first allocation from the
//! historical peak distribution so as to minimise expected cost under the
//! slow-peaks model (tasks fail at the end of their run and are retried
//! at a guaranteed-safe value). Upon failure, Tovar-PPM allocates the
//! machine maximum; PPM-Improved instead doubles the failed allocation —
//! the only difference between the two, and per the paper the reason
//! PPM-Improved wins by a wide margin on 128 GB nodes.

use crate::predictor::Predictor;
use crate::segments::StepPlan;
use crate::trace::Execution;

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RetryMode {
    /// Original Tovar et al.: jump straight to the machine maximum.
    MachineMax,
    /// PPM-Improved: double the previous allocation.
    Double,
}

pub struct TovarPpm {
    capacity: f64,
    mode: RetryMode,
    /// Chosen first-allocation value, GB.
    first_alloc: f64,
    /// Mean duration, used to weight failure cost.
    mean_duration: f64,
}

impl TovarPpm {
    pub fn new(capacity: f64, mode: RetryMode) -> Self {
        TovarPpm { capacity, mode, first_alloc: 1.0, mean_duration: 1.0 }
    }

    /// Expected wastage of requesting `v` against the observed peaks,
    /// under the slow-peaks model: successes waste (v - p) for the whole
    /// run; failures waste the full request plus a safe retry at
    /// `retry_value` wasting (retry_value - p).
    fn expected_cost(&self, v: f64, peaks: &[f64], retry_value: f64) -> f64 {
        let mut cost = 0.0;
        for &p in peaks {
            if p <= v {
                cost += v - p;
            } else {
                cost += v + (retry_value - p).max(0.0);
            }
        }
        cost / peaks.len() as f64
    }
}

impl Predictor for TovarPpm {
    fn name(&self) -> &'static str {
        match self.mode {
            RetryMode::MachineMax => "tovar-ppm",
            RetryMode::Double => "ppm-improved",
        }
    }

    fn train(&mut self, history: &[Execution]) {
        if history.is_empty() {
            self.first_alloc = self.capacity;
            return;
        }
        let peaks: Vec<f64> = history.iter().map(|e| e.peak()).collect();
        self.mean_duration =
            history.iter().map(|e| e.duration()).sum::<f64>() / history.len() as f64;
        // Candidate values: every observed peak (the optimum of the
        // piecewise-linear cost lies on one), slightly padded so equal
        // future peaks still fit.
        let retry_value = match self.mode {
            RetryMode::MachineMax => self.capacity,
            RetryMode::Double => 0.0, // doubling retries approximated as 2v in cost
        };
        let mut best_v = self.capacity;
        let mut best_c = f64::INFINITY;
        for &cand in &peaks {
            let v = cand * 1.02;
            let rv = match self.mode {
                RetryMode::MachineMax => retry_value,
                RetryMode::Double => (v * 2.0).min(self.capacity),
            };
            let c = self.expected_cost(v, &peaks, rv);
            if c < best_c {
                best_c = c;
                best_v = v;
            }
        }
        self.first_alloc = best_v.min(self.capacity);
    }

    fn plan(&self, _input_mb: f64) -> StepPlan {
        StepPlan::flat(self.first_alloc)
    }

    fn on_failure(&self, prev: &StepPlan, _fail_time: f64, _attempt: usize) -> StepPlan {
        match self.mode {
            RetryMode::MachineMax => StepPlan::flat(self.capacity),
            RetryMode::Double => {
                let prev_peak = prev.last_peak_or(self.first_alloc);
                StepPlan::flat((prev_peak * 2.0).min(self.capacity))
            }
        }
    }

    fn capacity(&self) -> f64 {
        self.capacity
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn hist(rng: &mut Rng, n: usize) -> Vec<Execution> {
        (0..n)
            .map(|_| {
                let p = rng.uniform(4.0, 12.0);
                Execution::new("t", 1000.0, 1.0, vec![p * 0.6, p])
            })
            .collect()
    }

    #[test]
    fn first_alloc_within_peak_range() {
        let mut rng = Rng::new(1);
        let h = hist(&mut rng, 100);
        let mut p = TovarPpm::new(128.0, RetryMode::MachineMax);
        p.train(&h);
        let v = p.plan(0.0).peaks[0];
        assert!((4.0..=13.0).contains(&v), "first alloc {v}");
    }

    #[test]
    fn tovar_retry_is_machine_max() {
        let p = TovarPpm::new(128.0, RetryMode::MachineMax);
        let retry = p.on_failure(&StepPlan::flat(8.0), 10.0, 1);
        assert_eq!(retry, StepPlan::flat(128.0));
    }

    #[test]
    fn improved_retry_doubles() {
        let p = TovarPpm::new(128.0, RetryMode::Double);
        let retry = p.on_failure(&StepPlan::flat(8.0), 10.0, 1);
        assert_eq!(retry, StepPlan::flat(16.0));
        let capped = p.on_failure(&StepPlan::flat(100.0), 10.0, 2);
        assert_eq!(capped, StepPlan::flat(128.0));
    }

    #[test]
    fn untrained_allocates_capacity() {
        let mut p = TovarPpm::new(128.0, RetryMode::MachineMax);
        p.train(&[]);
        assert_eq!(p.plan(0.0), StepPlan::flat(128.0));
    }

    #[test]
    fn improved_picks_lower_first_alloc_than_tovar() {
        // With a cheap doubling retry, under-provisioning is less costly,
        // so PPM-Improved should never pick a *higher* first allocation.
        let mut rng = Rng::new(3);
        let h = hist(&mut rng, 200);
        let mut tovar = TovarPpm::new(128.0, RetryMode::MachineMax);
        tovar.train(&h);
        let mut improved = TovarPpm::new(128.0, RetryMode::Double);
        improved.train(&h);
        assert!(
            improved.first_alloc <= tovar.first_alloc + 1e-9,
            "improved {} > tovar {}",
            improved.first_alloc,
            tovar.first_alloc
        );
    }

    #[test]
    fn plan_ignores_input_size() {
        let mut rng = Rng::new(4);
        let mut p = TovarPpm::new(128.0, RetryMode::Double);
        p.train(&hist(&mut rng, 50));
        assert_eq!(p.plan(10.0), p.plan(100000.0));
    }

    #[test]
    fn expected_cost_prefers_covering_tight_cluster() {
        // Peaks tightly clustered at 8: the cost optimum must cover them
        // (failures are expensive), not sit at the minimum.
        let peaks = vec![7.9, 8.0, 8.1, 8.05, 7.95];
        let h: Vec<Execution> = peaks
            .iter()
            .map(|&p| Execution::new("t", 1.0, 1.0, vec![p]))
            .collect();
        let mut t = TovarPpm::new(128.0, RetryMode::MachineMax);
        t.train(&h);
        assert!(t.first_alloc >= 8.1, "first alloc {} fails most tasks", t.first_alloc);
    }
}
