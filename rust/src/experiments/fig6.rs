//! Fig 6: aggregated memory wastage (GB*s) per method, workflow, and
//! training fraction, averaged over the split seeds.
//!
//! Paper headline (shape to reproduce):
//! - KS+ lowest everywhere;
//! - vs best baseline (k-Segments Selective): eager -36/-39/-40 %,
//!   sarek -31/-28/-29 %;
//! - vs best peak-only baseline (PPM-Improved): eager about -51 %,
//!   sarek about -45 %;
//! - PPM-Improved far below Tovar-PPM (the machine-max retry hurts on
//!   128 GB nodes); Default can beat Tovar-PPM on sarek.

use anyhow::Result;

use crate::experiments::{eval_traces, evaluate_method, report, ExpConfig, ExpOutput};
use crate::metrics::relative_reduction;
use crate::predictor::paper_methods;
use crate::util::json::Json;
use crate::util::stats;

/// One (workflow, method, frac) cell: per-seed total wastage.
#[derive(Debug, Clone)]
pub struct Cell {
    pub workflow: &'static str,
    pub method: &'static str,
    pub train_frac: f64,
    pub wastage_gbs: Vec<f64>,
    pub failures: Vec<f64>,
}

pub fn collect(cfg: &ExpConfig) -> Result<Vec<Cell>> {
    collect_methods(cfg, &paper_methods())
}

pub fn collect_methods(cfg: &ExpConfig, methods: &[&'static str]) -> Result<Vec<Cell>> {
    let mut cells = Vec::new();
    for (wf, trace, label) in eval_traces(cfg)? {
        for &frac in &cfg.train_fracs {
            for &method in methods {
                let mut wastage = Vec::with_capacity(cfg.seeds.len());
                let mut failures = Vec::with_capacity(cfg.seeds.len());
                for &seed in &cfg.seeds {
                    let r = evaluate_method(
                        method,
                        cfg.k,
                        cfg.capacity_gb,
                        &wf,
                        &trace,
                        frac,
                        seed,
                    )?;
                    wastage.push(r.total_wastage_gbs());
                    failures.push(r.total_failures() as f64);
                }
                cells.push(Cell {
                    workflow: label,
                    method,
                    train_frac: frac,
                    wastage_gbs: wastage,
                    failures,
                });
            }
        }
    }
    Ok(cells)
}

/// Workflow labels present in the cells, in first-appearance order
/// (the synthetic pair, or just "trace" for an ingested CSV).
fn labels(cells: &[Cell]) -> Vec<&'static str> {
    let mut out = Vec::new();
    for c in cells {
        if !out.contains(&c.workflow) {
            out.push(c.workflow);
        }
    }
    out
}

/// Extended Fig 6: adds the Witt LR related-work baselines and the
/// dynamic-k KS+ variant (future work) to the paper's method set.
pub fn run_extended(cfg: &ExpConfig) -> Result<ExpOutput> {
    let methods = crate::predictor::all_methods();
    let cells = collect_methods(cfg, &methods)?;
    let mut text = String::new();
    let mut json_rows = Vec::new();
    for wf_name in labels(&cells) {
        let mut table = report::Table::new(&["method", "train%", "wastage GBs", "failures"]);
        for &frac in &cfg.train_fracs {
            for &method in &methods {
                let cell = cells
                    .iter()
                    .find(|c| c.workflow == wf_name && c.method == method && c.train_frac == frac)
                    .unwrap();
                table.row(vec![
                    method.to_string(),
                    format!("{:.0}", frac * 100.0),
                    report::mean_pm_std(&cell.wastage_gbs),
                    report::f(stats::mean(&cell.failures)),
                ]);
                json_rows.push(Json::obj(vec![
                    ("workflow", wf_name.into()),
                    ("method", method.into()),
                    ("train_frac", cell.train_frac.into()),
                    ("wastage_gbs_mean", stats::mean(&cell.wastage_gbs).into()),
                ]));
            }
        }
        text.push_str(&table.render(&format!("Fig 6-extended ({wf_name})")));
        text.push('\n');
    }
    Ok(ExpOutput { text, json: Json::obj(vec![("fig6x", Json::Arr(json_rows))]) })
}

pub fn run(cfg: &ExpConfig) -> Result<ExpOutput> {
    let cells = collect(cfg)?;
    let mut text = String::new();
    let mut json_rows = Vec::new();

    for wf_name in labels(&cells) {
        let mut table = report::Table::new(&["method", "train%", "wastage GBs", "failures"]);
        for &frac in &cfg.train_fracs {
            for method in paper_methods() {
                let cell = cells
                    .iter()
                    .find(|c| c.workflow == wf_name && c.method == method && c.train_frac == frac)
                    .unwrap();
                table.row(vec![
                    method.to_string(),
                    format!("{:.0}", frac * 100.0),
                    report::mean_pm_std(&cell.wastage_gbs),
                    report::f(stats::mean(&cell.failures)),
                ]);
                json_rows.push(Json::obj(vec![
                    ("workflow", wf_name.into()),
                    ("method", method.into()),
                    ("train_frac", cell.train_frac.into()),
                    ("wastage_gbs_mean", stats::mean(&cell.wastage_gbs).into()),
                    ("wastage_gbs_std", stats::stddev(&cell.wastage_gbs).into()),
                    ("failures_mean", stats::mean(&cell.failures).into()),
                ]));
            }
        }
        text.push_str(&table.render(&format!("Fig 6 ({wf_name}): aggregated wastage")));

        // Headline reductions per fraction.
        for &frac in &cfg.train_fracs {
            let w = |m: &str| {
                stats::mean(
                    &cells
                        .iter()
                        .find(|c| c.workflow == wf_name && c.method == m && c.train_frac == frac)
                        .unwrap()
                        .wastage_gbs,
                )
            };
            let ks = w("ksplus");
            let best_baseline = paper_methods()
                .iter()
                .filter(|m| **m != "ksplus")
                .map(|m| w(m))
                .fold(f64::INFINITY, f64::min);
            text.push_str(&format!(
                "  {}% train: KS+ vs best baseline: {:+.0}%  vs PPM-Improved: {:+.0}%\n",
                frac * 100.0,
                -relative_reduction(ks, best_baseline) * 100.0,
                -relative_reduction(ks, w("ppm-improved")) * 100.0,
            ));
        }
        text.push('\n');
    }

    Ok(ExpOutput { text, json: Json::obj(vec![("fig6", Json::Arr(json_rows))]) })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ExpConfig {
        ExpConfig { seeds: vec![1], train_fracs: vec![0.5], ..Default::default() }
    }

    #[test]
    fn produces_cell_per_method() {
        let cells = collect(&tiny_cfg()).unwrap();
        // 2 workflows x 1 frac x 6 methods
        assert_eq!(cells.len(), 12);
        assert!(cells.iter().all(|c| c.wastage_gbs.len() == 1));
        assert!(cells.iter().all(|c| c.wastage_gbs[0] > 0.0));
    }

    #[test]
    fn ksplus_beats_peak_baselines_eager() {
        let cells = collect(&tiny_cfg()).unwrap();
        let w = |m: &str| {
            cells
                .iter()
                .find(|c| c.workflow == "eager" && c.method == m)
                .unwrap()
                .wastage_gbs[0]
        };
        assert!(
            w("ksplus") < w("ppm-improved"),
            "KS+ {} !< PPM-Improved {}",
            w("ksplus"),
            w("ppm-improved")
        );
        assert!(w("ksplus") < w("tovar-ppm"));
        assert!(w("ksplus") < w("default"));
    }

    #[test]
    fn report_renders() {
        let out = run(&tiny_cfg()).unwrap();
        assert!(out.text.contains("Fig 6 (eager)"));
        assert!(out.text.contains("ksplus"));
        assert!(out.json.get("fig6").is_some());
    }

    #[test]
    fn trace_csv_drives_fig6() {
        let cfg = ExpConfig {
            trace_csv: Some(
                concat!(
                    env!("CARGO_MANIFEST_DIR"),
                    "/../golden/traces/nfcore_rnaseq_sample.csv"
                )
                .into(),
            ),
            ..tiny_cfg()
        };
        let out = run(&cfg).unwrap();
        assert!(out.text.contains("Fig 6 (trace)"), "{}", out.text);
        assert!(!out.text.contains("sarek"));
        let cells = collect(&cfg).unwrap();
        // 1 trace x 1 frac x 6 methods.
        assert_eq!(cells.len(), 6);
        assert!(cells.iter().all(|c| c.workflow == "trace"));
    }
}
