//! DAG-aware workflow execution on the cluster: task instances become
//! ready only when their upstream instances finish, as a real SWMS
//! (Nextflow) would schedule them. Built on `cluster::run_cluster` by
//! executing the workflow stage-by-stage in topological order and
//! accumulating per-stage cluster results.
//!
//! This intentionally models nf-core's per-sample channels: instance `i`
//! of a task consumes instance `i` of each upstream task, so a stage can
//! start only after the previous stage's instances are done (barrier per
//! dependency edge). A finer event-level DAG would overlap stages; the
//! barrier model is conservative and keeps makespans comparable across
//! methods.

use crate::metrics::WastageReport;
use crate::sim::cluster::{run_cluster, ClusterConfig, ClusterResult, PredictorSource};
use crate::trace::workflow::Workflow;
use crate::trace::WorkflowTrace;

/// Result of a DAG-ordered workflow run.
#[derive(Debug, Clone)]
pub struct DagResult {
    /// Sum of stage makespans (critical path under the barrier model).
    pub makespan_s: f64,
    pub report: WastageReport,
    /// (task, stage makespan, stage throughput) per topological stage.
    pub stages: Vec<(String, f64, f64)>,
}

/// Execute every instance of `trace` on the cluster in topological
/// stage order.
pub fn run_workflow_dag(
    cfg: &ClusterConfig,
    wf: &Workflow,
    trace: &WorkflowTrace,
    predictors: &dyn PredictorSource,
) -> DagResult {
    let mut makespan = 0.0;
    let mut report = WastageReport::default();
    let mut stages = Vec::new();
    for task in wf.topo_order() {
        let Some(tt) = trace.task(task) else { continue };
        if tt.executions.is_empty() {
            continue;
        }
        let r: ClusterResult = run_cluster(cfg, predictors, &tt.executions);
        for o in &r.outcomes {
            report.add(o);
        }
        makespan += r.makespan_s;
        stages.push((task.to_string(), r.makespan_s, r.throughput_per_h));
    }
    DagResult { makespan_s: makespan, report, stages }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::trained_predictor;
    use crate::predictor::Predictor;
    use crate::trace::split_train_test;
    use crate::util::rng::Rng;
    use std::collections::BTreeMap;

    struct Trained(BTreeMap<String, Box<dyn Predictor>>);

    impl PredictorSource for Trained {
        fn get(&self, task: &str) -> Option<&dyn Predictor> {
            self.0.get(task).map(|p| p.as_ref())
        }
    }

    fn setup(method: &str) -> (Workflow, WorkflowTrace, Trained) {
        let wf = Workflow::eager();
        let full = wf.generate(3, 80);
        let mut preds = Trained(BTreeMap::new());
        let mut test = WorkflowTrace { name: full.name.clone(), tasks: Vec::new() };
        for (idx, t) in full.tasks.iter().enumerate() {
            let mut rng = Rng::new(1).fork(idx as u64);
            let (train, test_set) = split_train_test(t, 0.5, &mut rng);
            preds.0.insert(
                t.task.clone(),
                trained_predictor(method, 4, 128.0, &wf, &t.task, &train).unwrap(),
            );
            test.tasks.push(crate::trace::TaskTraces {
                task: t.task.clone(),
                executions: test_set.into_iter().take(6).collect(),
            });
        }
        (wf, test, preds)
    }

    #[test]
    fn all_stages_execute_in_topo_order() {
        let (wf, test, preds) = setup("ksplus");
        let cfg = ClusterConfig { nodes: 2, node_capacity_gb: 128.0 };
        let r = run_workflow_dag(&cfg, &wf, &test, &preds);
        assert_eq!(r.stages.len(), 9);
        // Stage order respects the DAG.
        let order: Vec<&str> = r.stages.iter().map(|(t, _, _)| t.as_str()).collect();
        for (u, d) in &wf.edges {
            let pu = order.iter().position(|t| t == u).unwrap();
            let pd = order.iter().position(|t| t == d).unwrap();
            assert!(pu < pd, "{u} must run before {d}");
        }
        assert!(r.makespan_s > 0.0);
        assert_eq!(r.report.total_instances(), 9 * 6);
        assert!(r.report.per_task.values().all(|a| a.unfinished == 0));
    }

    #[test]
    fn makespan_is_sum_of_stages() {
        let (wf, test, preds) = setup("ppm-improved");
        let cfg = ClusterConfig { nodes: 2, node_capacity_gb: 128.0 };
        let r = run_workflow_dag(&cfg, &wf, &test, &preds);
        let sum: f64 = r.stages.iter().map(|(_, m, _)| m).sum();
        assert!((sum - r.makespan_s).abs() < 1e-9);
    }

    #[test]
    fn tighter_plans_do_not_hurt_dag_makespan() {
        let cfg = ClusterConfig { nodes: 1, node_capacity_gb: 128.0 };
        let (wf, test, ks) = setup("ksplus");
        let ks_r = run_workflow_dag(&cfg, &wf, &test, &ks);
        let (_, _, flat) = setup("default");
        let flat_r = run_workflow_dag(&cfg, &wf, &test, &flat);
        // KS+ wastes less and (with memory-bound packing) is at least
        // competitive on makespan.
        assert!(ks_r.report.total_wastage_gbs() < flat_r.report.total_wastage_gbs());
        assert!(ks_r.makespan_s <= flat_r.makespan_s * 1.3);
    }
}
