//! Bench for Fig 6: end-to-end evaluation of every method on both
//! workflows (one seed, 50 % train) — the paper's main figure, timed.
//!
//! Prints both the wastage rows (shape check against the paper) and the
//! wall-clock cost per method evaluation.

use ksplus::experiments::{evaluate_method, ExpConfig};
use ksplus::predictor::paper_methods;
use ksplus::trace::workflow::Workflow;
use ksplus::util::bench::{bench, black_box};

fn main() {
    let cfg = ExpConfig::default();
    for wf in [Workflow::eager(), Workflow::sarek()] {
        let trace = wf.generate(cfg.trace_seed, cfg.target_samples);
        println!("== fig6 bench: {} ==", wf.name);
        for method in paper_methods() {
            let mut wastage = 0.0;
            let r = bench(&format!("{}/{method}", wf.name), 1, 5, || {
                let rep =
                    evaluate_method(method, cfg.k, cfg.capacity_gb, &wf, &trace, 0.5, 1)
                        .unwrap();
                wastage = black_box(rep.total_wastage_gbs());
            });
            println!(
                "  -> {method}: {:.0} GBs wastage, {:.1} ms/eval",
                wastage,
                r.median_s * 1e3
            );
        }
    }
}
