//! Cross-wire, cross-front-end parity: the same session script driven
//! over threaded-v1, threaded-v2, eventloop-v1, and eventloop-v2 must
//! produce bit-identical results — every plan's f64s compared via
//! `to_bits`, provenance strings, error codes, counters. The wire and
//! the front end are transport; if either changes a single bit of a
//! plan, that is a codec bug, not a rounding difference.
//!
//! Also pins the hello negotiation matrix over a live socket on both
//! front ends.

use std::time::Duration;

#[cfg(unix)]
use ksplus::coordinator::eventloop::EventLoopServer;
use ksplus::coordinator::protocol::{ErrorCode, Request};
use ksplus::coordinator::remote::RemoteClient;
use ksplus::coordinator::server::Server;
use ksplus::coordinator::service::{Client, Coordinator, CoordinatorConfig};
use ksplus::coordinator::wire::Wire;
use ksplus::coordinator::{BackendSpec, PredictorPolicy};
use ksplus::segments::StepPlan;
use ksplus::trace::Execution;
use ksplus::util::json::Json;

const SHARDS: usize = 2;
const TIMEOUT: Duration = Duration::from_secs(10);

/// Either front end, so one test body can iterate over both.
enum Front {
    Threaded(Server),
    #[cfg(unix)]
    Event(EventLoopServer),
}

impl Front {
    fn addr(&self) -> std::net::SocketAddr {
        match self {
            Front::Threaded(s) => s.addr(),
            #[cfg(unix)]
            Front::Event(s) => s.addr(),
        }
    }
}

#[cfg(unix)]
fn start_event_front(client: Client) -> Front {
    Front::Event(EventLoopServer::start("127.0.0.1:0", client).unwrap())
}

#[cfg(not(unix))]
fn start_event_front(_client: Client) -> Front {
    unreachable!("eventloop combos are not generated on this platform")
}

/// A fresh coordinator (deterministic: same config, same training
/// below) behind the requested front end.
fn start(threaded: bool) -> (Coordinator, Front) {
    let coord = Coordinator::start(
        CoordinatorConfig { k: 3, shards: SHARDS, ..Default::default() },
        BackendSpec::Native,
    )
    .unwrap();
    let front = if threaded {
        Front::Threaded(Server::start("127.0.0.1:0", coord.client()).unwrap())
    } else {
        start_event_front(coord.client())
    };
    (coord, front)
}

/// The (label, front end, wire) combinations under test. The first
/// entry is the baseline the others must match bit-for-bit.
fn combos() -> Vec<(&'static str, bool, Wire)> {
    let mut v = vec![("threaded-v1", true, Wire::V1), ("threaded-v2", true, Wire::V2)];
    #[cfg(unix)]
    {
        v.push(("eventloop-v1", false, Wire::V1));
        v.push(("eventloop-v2", false, Wire::V2));
    }
    v
}

/// Deterministic two-phase history — same bytes into every combo.
fn history(n: usize) -> Vec<Execution> {
    (0..n)
        .map(|i| {
            let input = 1000.0 + 750.0 * i as f64;
            let len = 5 + i % 4;
            let samples: Vec<f64> = (0..len)
                .map(|j| 0.0007 * input * if j < len / 2 { 0.6 } else { 1.3 })
                .collect();
            Execution::new("t", input, 1.0, samples)
        })
        .collect()
}

/// Canonical exact-bits form of a plan: any formatting rounding would
/// defeat the comparison, so hash the raw f64 bit patterns.
fn plan_key(p: &StepPlan) -> String {
    let starts: Vec<u64> = p.starts.iter().map(|f| f.to_bits()).collect();
    let peaks: Vec<u64> = p.peaks.iter().map(|f| f.to_bits()).collect();
    format!("{starts:?}/{peaks:?}")
}

/// Run the full session script over one connection and record every
/// observable result as a line. Two combos are in parity iff their
/// line vectors are equal.
fn drive_session(addr: std::net::SocketAddr, wire: Wire) -> Vec<String> {
    let mut rc = RemoteClient::connect_with_timeout(addr, TIMEOUT).unwrap();
    let info = rc.negotiate(wire.version()).unwrap();
    assert_eq!(info.version, wire.version(), "negotiation granted the wrong wire");
    assert_eq!(rc.wire(), wire);
    let mut out = Vec::new();
    // The negotiated version is the one per-combo difference; everything
    // recorded below must be identical across combos.
    out.push(format!("hello: ops={} policies={} shards={}", info.ops.len(),
        info.policies.len(), info.shards));

    rc.configure(Some("par-ks"), PredictorPolicy::KsPlus).unwrap();
    rc.configure(Some("par-witt"), PredictorPolicy::WittLr).unwrap();
    let hist = history(12);
    out.push(format!("train par-ks: {}", rc.train("par-ks", &hist).unwrap()));
    out.push(format!("train par-witt: {}", rc.train("par-witt", &hist).unwrap()));

    let ack = rc.observe("par-ks", &hist[3]).unwrap();
    out.push(format!(
        "observe: task={} executions={} predictor={}",
        ack.task, ack.executions, ack.predictor
    ));

    for task in ["par-ks", "par-witt", "par-missing"] {
        for input in [1500.0, 4096.5, 9000.25] {
            let o = rc.plan(task, input).unwrap();
            out.push(format!(
                "plan {task}/{input}: {} v{} fb={:?} {}",
                o.predictor,
                o.model_version,
                o.fallback_reason,
                plan_key(&o.plan)
            ));
        }
    }

    let base = rc.plan("par-ks", 5000.0).unwrap();
    let retry = rc.report_failure(Some("par-ks"), &base.plan, 30.0).unwrap();
    out.push(format!("retry par-ks: {} {}", retry.predictor, plan_key(&retry.plan)));
    let prev = StepPlan::new(vec![0.0, 100.0], vec![2.0, 8.0]);
    let retry = rc.report_failure(None, &prev, 60.0).unwrap();
    out.push(format!("retry default: {} {}", retry.predictor, plan_key(&retry.plan)));

    // Semantic error classes, typed so both wires can express them; the
    // structured code must not depend on the framing.
    for (req, label) in [
        (Request::Train { task: "x".into(), history: vec![], dedup: None }, "empty-train"),
        (Request::Reshard { shards: 0 }, "reshard-0"),
        (
            Request::Configure {
                task: Some("*".into()),
                policy: PredictorPolicy::KsPlus,
                dedup: None,
            },
            "configure-star",
        ),
        (Request::Hello { client: None, min_version: Some(99), max_version: None },
            "hello-99"),
    ] {
        let err = rc.call_raw(&req).unwrap().unwrap_err();
        out.push(format!("error {label}: {}", err.code.as_str()));
    }

    let doc = rc.snapshot().unwrap();
    out.push(format!(
        "snapshot: schema={:?} tasks={}",
        doc.get("schema").and_then(Json::as_str),
        doc.get("tasks").and_then(Json::as_arr).map(|a| a.len()).unwrap_or(0)
    ));

    // Reshard round trip: plans must be bit-stable across both moves.
    let ids = rc.reshard(SHARDS + 1).unwrap();
    out.push(format!("reshard grow: {}", ids.len()));
    out.push(format!("plan after grow: {}", plan_key(&rc.plan("par-ks", 7000.0).unwrap().plan)));
    let ids = rc.reshard(SHARDS).unwrap();
    out.push(format!("reshard shrink: {}", ids.len()));
    out.push(format!(
        "plan after shrink: {}",
        plan_key(&rc.plan("par-ks", 7000.0).unwrap().plan)
    ));

    let s = rc.stats().unwrap();
    out.push(format!(
        "stats: shards={} requests={} trained={} observations={} fallbacks={} \
         failures={} refused={} timeouts={}",
        s.shards,
        s.requests,
        s.tasks_trained,
        s.observations,
        s.fallbacks,
        s.failures_handled,
        s.conns_refused,
        s.conn_timeouts
    ));
    out
}

#[test]
fn same_session_is_bit_identical_across_front_ends_and_wires() {
    let mut baseline: Option<(&'static str, Vec<String>)> = None;
    for (label, threaded, wire) in combos() {
        let (_coord, front) = start(threaded);
        let got = drive_session(front.addr(), wire);
        // Spot-check the script itself produced real content before
        // comparing: plans from both policies plus the fallback.
        assert!(got.iter().any(|l| l.contains("plan par-ks") && l.contains("ksplus")), "{label}");
        assert!(
            got.iter().any(|l| l.contains("plan par-missing") && l.contains("untrained-task")),
            "{label}"
        );
        match &baseline {
            None => baseline = Some((label, got)),
            Some((base_label, want)) => {
                assert_eq!(
                    &got, want,
                    "session trace over {label} diverged from {base_label}"
                );
            }
        }
    }
}

/// The hello negotiation matrix, over a live socket: conservative
/// defaults (absent fields mean v1), explicit v2 opt-in, and the error
/// classes for impossible ranges. Sent as raw v1 lines so absent fields
/// really are absent.
fn negotiation_matrix(addr: std::net::SocketAddr) {
    let grants: &[(&str, usize)] = &[
        (r#"{"op":"hello"}"#, 1),
        (r#"{"op":"hello","min_version":1}"#, 1),
        (r#"{"op":"hello","min_version":1,"max_version":1}"#, 1),
        (r#"{"op":"hello","max_version":2}"#, 2),
        (r#"{"op":"hello","min_version":1,"max_version":2}"#, 2),
        (r#"{"op":"hello","min_version":2,"max_version":2}"#, 2),
        (r#"{"op":"hello","min_version":2}"#, 2),
        (r#"{"op":"hello","max_version":99}"#, 2),
    ];
    for (line, want) in grants {
        // Fresh connection per case: a granted v2 switches the server
        // side's codec, after which raw v1 lines would be framing
        // garbage.
        let mut rc = RemoteClient::connect_with_timeout(addr, TIMEOUT).unwrap();
        let j = rc.raw(line).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "{line} -> {j}");
        assert_eq!(
            j.get("version").and_then(Json::as_usize),
            Some(*want),
            "{line} -> {j}"
        );
    }
    let errors: &[(&str, &str)] = &[
        (r#"{"op":"hello","min_version":3,"max_version":1}"#, "invalid-field"),
        (r#"{"op":"hello","min_version":99}"#, "unsupported-version"),
        (r#"{"op":"hello","max_version":0}"#, "unsupported-version"),
    ];
    for (line, want) in errors {
        let mut rc = RemoteClient::connect_with_timeout(addr, TIMEOUT).unwrap();
        let j = rc.raw(line).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(false)), "{line} -> {j}");
        let code = j.get("error").and_then(|e| e.get("code")).and_then(Json::as_str);
        assert_eq!(code, Some(*want), "{line} -> {j}");
    }
    // A failed negotiation must leave the connection serviceable on v1.
    let mut rc = RemoteClient::connect_with_timeout(addr, TIMEOUT).unwrap();
    let err = rc
        .call_raw(&Request::Hello { client: None, min_version: Some(99), max_version: None })
        .unwrap()
        .unwrap_err();
    assert_eq!(err.code, ErrorCode::UnsupportedVersion);
    let info = rc.hello().unwrap();
    assert_eq!(info.version, 1);
}

#[test]
fn negotiation_matrix_over_threaded_server() {
    let (_coord, front) = start(true);
    negotiation_matrix(front.addr());
}

#[cfg(unix)]
#[test]
fn negotiation_matrix_over_eventloop_server() {
    let (_coord, front) = start(false);
    negotiation_matrix(front.addr());
}
