//! Full-workflow scenario: run the eager workflow's test instances
//! through the discrete-event cluster simulator under every method and
//! compare wastage, failures, makespan, and throughput — the cluster-level
//! consequence of better memory prediction that the paper's introduction
//! motivates.
//!
//! ```sh
//! cargo run --release --example eager_workflow
//! ```

use std::collections::BTreeMap;

use ksplus::experiments::trained_predictor;
use ksplus::predictor::{paper_methods, Predictor};
use ksplus::sim::cluster::{run_cluster, ClusterConfig, PredictorSource};
use ksplus::trace::workflow::Workflow;
use ksplus::trace::split_train_test;
use ksplus::util::rng::Rng;

struct Trained(BTreeMap<String, Box<dyn Predictor>>);

impl PredictorSource for Trained {
    fn get(&self, task: &str) -> Option<&dyn Predictor> {
        self.0.get(task).map(|p| p.as_ref())
    }
}

fn main() -> anyhow::Result<()> {
    let wf = Workflow::eager();
    let trace = wf.generate(42, 200);
    let cluster = ClusterConfig { nodes: 4, node_capacity_gb: 128.0 };
    println!(
        "eager workflow: {} task instances on {} x {:.0} GB nodes\n",
        trace.total_instances(),
        cluster.nodes,
        cluster.node_capacity_gb
    );
    println!(
        "{:>20}  {:>10} {:>9} {:>9} {:>11} {:>10}",
        "method", "wastage", "failures", "makespan", "throughput", "efficiency"
    );

    for method in paper_methods() {
        // Train per task on a 50 % split (seeded identically per method).
        let mut predictors = Trained(BTreeMap::new());
        let mut test = Vec::new();
        for (idx, t) in trace.tasks.iter().enumerate() {
            let mut rng = Rng::new(7).fork(idx as u64 + 1);
            let (train_set, test_set) = split_train_test(t, 0.5, &mut rng);
            let pred = trained_predictor(method, 4, cluster.node_capacity_gb, &wf, &t.task, &train_set)?;
            predictors.0.insert(t.task.clone(), pred);
            test.extend(test_set);
        }
        let r = run_cluster(&cluster, &predictors, &test);
        println!(
            "{:>20}  {:>7.0}GBs {:>9} {:>8.0}s {:>8.1}/h {:>9.1}%",
            method,
            r.report.total_wastage_gbs(),
            r.report.total_failures(),
            r.makespan_s,
            r.throughput_per_h,
            r.report.efficiency() * 100.0,
        );
    }
    println!(
        "\nTighter plans pack more tasks per node: KS+ should show the\n\
         lowest wastage and the best (or near-best) makespan/throughput."
    );
    Ok(())
}
