//! Vendored, dependency-free subset of the `anyhow` API.
//!
//! The build environment is fully offline (no crates.io), so the crate
//! ships in-tree as a workspace path dependency under the same name the
//! real crate uses. Only the surface `ksplus` consumes is implemented:
//!
//! - `anyhow::Error` (context chain, `{}` outermost / `{:#}` full chain)
//! - `anyhow::Result<T>` with the default error parameter
//! - the `Context` extension trait on `Result` and `Option`
//! - the `anyhow!`, `bail!`, and `ensure!` macros
//! - blanket `From<E: std::error::Error>` so `?` converts freely
//!
//! Swapping back to the upstream crate is a one-line change in
//! `rust/Cargo.toml`; nothing here extends the upstream semantics.

use std::error::Error as StdError;
use std::fmt;

/// `Result` with `anyhow::Error` as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An error carrying a chain of context messages, outermost first.
pub struct Error {
    parts: Vec<String>,
}

impl Error {
    /// Build an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { parts: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.parts.insert(0, context.to_string());
        self
    }

    fn from_std<E: StdError>(e: E) -> Error {
        let mut parts = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            parts.push(s.to_string());
            src = s.source();
        }
        Error { parts }
    }

    /// The context/cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.parts.iter().map(|s| s.as_str())
    }

    /// The innermost (root) message of the chain.
    pub fn root_cause(&self) -> &str {
        self.parts.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.parts.join(": "))
        } else {
            write!(f, "{}", self.parts.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.parts.first().map(String::as_str).unwrap_or(""))?;
        if self.parts.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for p in &self.parts[1..] {
                write!(f, "\n    {p}")?;
            }
        }
        Ok(())
    }
}

// Like upstream anyhow: `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket impl coherent.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::from_std(e)
    }
}

mod ext {
    use super::{Error, StdError};

    /// Private conversion trait mirroring anyhow's `ext::StdError`:
    /// implemented for every std error AND for `Error` itself, so
    /// `Context` works uniformly on both.
    pub trait IntoError {
        fn into_error(self) -> Error;
    }

    impl<E: StdError + Send + Sync + 'static> IntoError for E {
        fn into_error(self) -> Error {
            Error::from_std(self)
        }
    }

    impl IntoError for Error {
        fn into_error(self) -> Error {
            self
        }
    }
}

/// Attach context to errors, on both `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: ext::IntoError> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an `Error` from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error built like `anyhow!`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::core::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!(concat!("condition failed: `", stringify!($cond), "`"));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e: Error = io_err().into();
        let e = e.context("opening config");
        assert_eq!(format!("{e}"), "opening config");
        assert_eq!(format!("{e:#}"), "opening config: missing file");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.chain().count(), 2);

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(format!("{e}"), "missing 7");
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<u32> {
            let n: u32 = "not a number".parse()?;
            Ok(n)
        }
        let e = inner().unwrap_err();
        assert!(!format!("{e}").is_empty());
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: usize) -> Result<usize> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert_eq!(format!("{}", f(3).unwrap_err()), "three is right out");
        assert_eq!(format!("{}", f(12).unwrap_err()), "x too big: 12");
        let e = anyhow!("plain {}", "fmt");
        assert_eq!(format!("{e}"), "plain fmt");
    }

    #[test]
    fn debug_shows_cause_chain() {
        let e: Error = Error::msg("root").context("mid").context("top");
        let d = format!("{e:?}");
        assert!(d.contains("top") && d.contains("Caused by") && d.contains("root"));
        assert_eq!(e.root_cause(), "root");
    }
}
