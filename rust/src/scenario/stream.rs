//! Lazy, seeded execution stream for a scenario.
//!
//! `ScenarioStream` is the allocation-lean producer behind million-task
//! replay: `fill_next` writes each execution into a caller-provided
//! `Execution` (task string and sample buffer reused via
//! `Execution::copy_from` / `Archetype::generate_with_input_into`), so
//! nothing per-item is materialised — there is never a million-element
//! Vec anywhere.
//!
//! Determinism: the stream RNG, the training-set RNG, and (for trace
//! sources) the split RNG are forked from `spec.seed` with distinct tags.
//! The stream is a pure function of the spec — the engine recreates an
//! identical stream per policy, giving the paired evaluation the paper
//! uses.
//!
//! Training sets deliberately come from the *unperturbed* base
//! distribution (synthetic: fresh per-task generations; trace: the train
//! side of `split_train_test`): heavy tails, drift, and storms are things
//! that happen to a deployed model, not things it gets to train on
//! up front. Online retraining (the engine's sliding window) is how a
//! model catches up.

use anyhow::{bail, Context, Result};

use super::{Kind, ScenarioSpec};
use crate::trace::synth::{Archetype, GenScratch};
use crate::trace::workflow::Workflow;
use crate::trace::{split_train_test, Execution, TaskTraces};
use crate::util::rng::Rng;

/// Fork tags separating the independent RNG streams of a scenario.
const TAG_STREAM: u64 = 0x5ce0;
const TAG_TRAIN: u64 = 0x7a19;

/// Cap on the heavy-tail input multiplier: keeps the stressed tail inside
/// "very painful" rather than "physically impossible" (a handful of
/// unfinishable giants would otherwise dominate every wastage column).
pub const HEAVY_TAIL_CAP: f64 = 20.0;

enum Source {
    /// Count-weighted synthetic archetypes of a named workflow.
    Synth { archetypes: Vec<Archetype>, cum: Vec<usize>, total: usize, scratch: GenScratch },
    /// Size-weighted resampling of an ingested trace's test split.
    Trace { tasks: Vec<TaskTraces>, cum: Vec<usize>, total: usize },
}

pub struct ScenarioStream {
    spec: ScenarioSpec,
    kind: Kind,
    source: Source,
    rng: Rng,
    /// Next stream position (0-based).
    i: usize,
    /// First position the drift shift applies to.
    drift_at: usize,
    group_left: usize,
    group_mult: f64,
    training: Vec<TaskTraces>,
}

impl ScenarioStream {
    pub fn new(spec: &ScenarioSpec) -> Result<ScenarioStream> {
        spec.validate()?;
        let kind = spec.kind();
        let mut training = Vec::new();
        let source = if let Some(path) = &spec.trace {
            let full = crate::trace::load_csv_auto(path, "scenario-trace")
                .with_context(|| format!("scenario trace {}", path.display()))?;
            let mut tasks = Vec::new();
            for (idx, t) in full.tasks.iter().enumerate() {
                if t.executions.len() < 2 {
                    eprintln!(
                        "warning: scenario trace task '{}' has {} execution(s); \
                         needs >= 2 for a train/test split, skipping",
                        t.task,
                        t.executions.len()
                    );
                    continue;
                }
                let mut split_rng = Rng::new(spec.seed).fork(TAG_TRAIN).fork(idx as u64 + 1);
                let (train, test) = split_train_test(t, spec.train_frac, &mut split_rng);
                training.push(TaskTraces { task: t.task.clone(), executions: train });
                tasks.push(TaskTraces { task: t.task.clone(), executions: test });
            }
            let mut cum = Vec::with_capacity(tasks.len());
            let mut total = 0usize;
            for t in &tasks {
                total += t.executions.len();
                cum.push(total);
            }
            if total == 0 {
                bail!(
                    "scenario trace {} has no task with >= 2 executions",
                    path.display()
                );
            }
            Source::Trace { tasks, cum, total }
        } else {
            let Some(wf) = Workflow::by_name(&spec.workflow) else {
                bail!("unknown workflow '{}'", spec.workflow);
            };
            let mut archetypes = Vec::with_capacity(wf.counts.len());
            let mut cum = Vec::with_capacity(wf.counts.len());
            let mut total = 0usize;
            for (idx, (name, count)) in wf.counts.iter().enumerate() {
                let Some(a) = wf.archetype(name) else {
                    bail!("workflow '{}' counts task '{name}' with no archetype", wf.name);
                };
                let mut train_rng = Rng::new(spec.seed).fork(TAG_TRAIN).fork(idx as u64 + 1);
                training.push(a.generate_many(
                    &mut train_rng,
                    spec.train_per_task,
                    spec.target_samples,
                ));
                archetypes.push(a.clone());
                total += count;
                cum.push(total);
            }
            Source::Synth { archetypes, cum, total, scratch: GenScratch::default() }
        };
        Ok(ScenarioStream {
            kind,
            source,
            rng: Rng::new(spec.seed).fork(TAG_STREAM),
            i: 0,
            drift_at: (spec.at * spec.n as f64) as usize,
            group_left: 0,
            group_mult: 1.0,
            training,
            spec: spec.clone(),
        })
    }

    /// The per-task training sets (unperturbed base distribution).
    pub fn training(&self) -> &[TaskTraces] {
        &self.training
    }

    /// Stream position: executions produced so far.
    pub fn position(&self) -> usize {
        self.i
    }

    /// Produce the next execution into `out`, reusing its buffers.
    pub fn fill_next(&mut self, out: &mut Execution) {
        let i = self.i;
        self.i += 1;
        let rng = &mut self.rng;
        match &mut self.source {
            Source::Synth { archetypes, cum, total, scratch } => {
                let pick = rng.below(*total);
                let a_idx = cum.partition_point(|&c| c <= pick);
                let a = &archetypes[a_idx];
                // Base input draw; heavy-tail swaps the lognormal for a
                // Pareto tail around the same median.
                let mut input = match self.kind {
                    Kind::HeavyTail => {
                        a.input_median_mb * rng.pareto(1.0, self.spec.alpha, HEAVY_TAIL_CAP)
                    }
                    _ => a.input_median_mb * rng.log_normal(0.0, a.input_sigma),
                };
                if self.kind == Kind::Correlated {
                    if self.group_left == 0 {
                        self.group_mult = rng.log_normal(0.0, self.spec.rho);
                        self.group_left = self.spec.group;
                    }
                    self.group_left -= 1;
                    input *= self.group_mult;
                }
                a.generate_with_input_into(rng, input, self.spec.target_samples, scratch, out);
            }
            Source::Trace { tasks, cum, total } => {
                let pick = rng.below(*total);
                let t_idx = cum.partition_point(|&c| c <= pick);
                let tt = &tasks[t_idx];
                let e_idx = rng.below(tt.executions.len());
                out.copy_from(&tt.executions[e_idx]);
                // Input multipliers on a recorded execution scale memory
                // proportionally (linear memory-vs-input assumption, the
                // same one the paper's predictors make).
                let mut m = 1.0;
                if self.kind == Kind::HeavyTail {
                    m = rng.pareto(1.0, self.spec.alpha, HEAVY_TAIL_CAP);
                }
                if self.kind == Kind::Correlated {
                    if self.group_left == 0 {
                        self.group_mult = rng.log_normal(0.0, self.spec.rho);
                        self.group_left = self.spec.group;
                    }
                    self.group_left -= 1;
                    m *= self.group_mult;
                }
                if m != 1.0 {
                    out.input_mb *= m;
                    for s in &mut out.samples {
                        *s *= m;
                    }
                }
            }
        }
        // Perturbations shared by both sources.
        match self.kind {
            Kind::Drift => {
                if i >= self.drift_at {
                    // Concept shift: memory per unit input jumps by
                    // `factor`; the input itself is unchanged, so
                    // input-aware models are genuinely wrong until they
                    // retrain on post-drift observations.
                    for s in &mut out.samples {
                        *s *= self.spec.factor;
                    }
                }
            }
            Kind::RetryStorm => {
                if self.rng.f64() < self.spec.prob {
                    for s in &mut out.samples {
                        *s *= self.spec.factor;
                    }
                }
            }
            Kind::Stragglers => {
                if self.rng.f64() < self.spec.prob {
                    out.dt *= self.spec.slow;
                }
            }
            _ => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::SCENARIO_NAMES;

    const GOLDEN_CSV: &str =
        concat!(env!("CARGO_MANIFEST_DIR"), "/../golden/traces/nfcore_rnaseq_sample.csv");

    fn collect(spec: &ScenarioSpec, n: usize) -> Vec<Execution> {
        let mut s = ScenarioStream::new(spec).unwrap();
        let mut out = Execution::new("", 0.0, 0.0, Vec::new());
        (0..n)
            .map(|_| {
                s.fill_next(&mut out);
                out.clone()
            })
            .collect()
    }

    #[test]
    fn every_transform_is_seed_deterministic() {
        for name in SCENARIO_NAMES {
            let spec = ScenarioSpec::parse(&format!("name={name},n=80,seed=11")).unwrap();
            let a = collect(&spec, 80);
            let b = collect(&spec, 80);
            assert_eq!(a, b, "stream of '{name}' not bit-identical across runs");
            let other = ScenarioSpec { seed: 12, ..spec.clone() };
            let c = collect(&other, 80);
            assert_ne!(a, c, "stream of '{name}' ignores the seed");
        }
    }

    #[test]
    fn every_trace_transform_is_seed_deterministic() {
        for name in SCENARIO_NAMES {
            let spec = ScenarioSpec::parse(&format!(
                "name={name},n=60,seed=3,trace={GOLDEN_CSV}"
            ))
            .unwrap();
            let a = collect(&spec, 60);
            let b = collect(&spec, 60);
            assert_eq!(a, b, "trace stream of '{name}' not bit-identical");
            // Trace tasks come from the CSV, not the synthetic workflow.
            assert!(a.iter().all(|e| {
                ["FASTQC", "STAR_ALIGN", "SALMON_QUANT"].contains(&e.task.as_str())
            }));
        }
    }

    #[test]
    fn transforms_actually_perturb() {
        let base = ScenarioSpec::parse("name=baseline,n=80,seed=11").unwrap();
        let a = collect(&base, 80);
        for name in SCENARIO_NAMES.iter().skip(1) {
            let spec = ScenarioSpec::parse(&format!("name={name},n=80,seed=11")).unwrap();
            let c = collect(&spec, 80);
            assert_ne!(a, c, "'{name}' left the stream untouched");
        }
    }

    #[test]
    fn drift_scales_exactly_after_the_shift_point() {
        // Drift consumes no extra RNG draws, so item-for-item the drift
        // stream equals baseline before `at`*n and baseline x factor
        // after.
        let base = ScenarioSpec::parse("name=baseline,n=40,seed=5").unwrap();
        let drift = ScenarioSpec::parse("name=drift,n=40,seed=5,at=0.5,factor=2.0").unwrap();
        let a = collect(&base, 40);
        let d = collect(&drift, 40);
        for i in 0..40 {
            if i < 20 {
                assert_eq!(a[i], d[i], "pre-drift item {i} differs");
            } else {
                assert_eq!(a[i].task, d[i].task);
                assert_eq!(a[i].input_mb, d[i].input_mb, "drift must not touch inputs");
                for (x, y) in a[i].samples.iter().zip(&d[i].samples) {
                    assert_eq!(*x * 2.0, *y, "post-drift item {i} not exactly doubled");
                }
            }
        }
    }

    #[test]
    fn heavy_tail_stretches_inputs() {
        let base = ScenarioSpec::parse("name=baseline,n=300,seed=9").unwrap();
        let tail = ScenarioSpec::parse("name=heavy-tail,n=300,seed=9,alpha=1.3").unwrap();
        let max_in = |v: &[Execution]| v.iter().map(|e| e.input_mb).fold(0.0, f64::max);
        let b = max_in(&collect(&base, 300));
        let t = max_in(&collect(&tail, 300));
        assert!(t > b * 1.5, "heavy tail max input {t} vs baseline {b}");
    }

    #[test]
    fn stragglers_stretch_durations_only() {
        let spec =
            ScenarioSpec::parse("name=stragglers,n=400,seed=2,prob=0.2,slow=4.0").unwrap();
        let base = ScenarioSpec::parse("name=baseline,n=400,seed=2").unwrap();
        let total = |v: &[Execution]| v.iter().map(|e| e.duration()).sum::<f64>();
        let s = collect(&spec, 400);
        let b = collect(&base, 400);
        assert!(total(&s) > total(&b) * 1.2, "{} vs {}", total(&s), total(&b));
        // Peaks are untouched by stragglers on matching draws: compare
        // only sample counts (dt changes, samples don't).
        assert!(s.iter().zip(&b).take(1).all(|(x, y)| x.samples == y.samples));
    }

    #[test]
    fn training_sets_are_per_task_and_deterministic() {
        let spec = ScenarioSpec::parse("name=baseline,train-per-task=10").unwrap();
        let s1 = ScenarioStream::new(&spec).unwrap();
        let s2 = ScenarioStream::new(&spec).unwrap();
        assert_eq!(s1.training().len(), 9); // eager task count
        for (a, b) in s1.training().iter().zip(s2.training()) {
            assert_eq!(a.task, b.task);
            assert_eq!(a.executions, b.executions);
            assert_eq!(a.executions.len(), 10);
        }
    }

    #[test]
    fn trace_stream_training_uses_split() {
        let spec =
            ScenarioSpec::parse(&format!("name=baseline,trace={GOLDEN_CSV}")).unwrap();
        let s = ScenarioStream::new(&spec).unwrap();
        // 4 instances per task, train-frac 0.5 -> 2 train per task.
        assert_eq!(s.training().len(), 3);
        assert!(s.training().iter().all(|t| t.executions.len() == 2));
    }

    #[test]
    fn missing_trace_file_errors() {
        let spec =
            ScenarioSpec::parse("name=baseline,trace=/nonexistent/nope.csv").unwrap();
        assert!(ScenarioStream::new(&spec).is_err());
    }
}
